"""Compressor-stack tests: wire round-trips for every registered codec,
error-feedback semantics, VJP equivalence, and per-contribution staleness
weighting through the federated runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core.quantizer import PQConfig
from repro.federated import wire

PQ = PQConfig(num_subvectors=8, num_clusters=4, kmeans_iters=2)


def _z(shape=(12, 64), seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def _all_specs():
    return ["none", "pq", "topk(k=0.1)", "scalarq(bits=8)",
            "chain:topk(k=0.25)+scalarq(bits=4)"]


# ---------------------------------------------------------------------------
# wire round-trips: bit-exact for every registered compressor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", _all_specs())
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_wire_roundtrip_bit_exact(spec, backend):
    """encode -> decode -> re-encode is byte-identical, and the decoded
    reconstruction matches the in-jit reconstruction (f32 wire dtype) for
    every codec, on both the jnp and pallas(-interpret) backends."""
    if spec == "pq":
        comp_obj = C.PQCompressor(
            cfg=PQConfig(num_subvectors=8, num_clusters=4, kmeans_iters=2,
                         backend=backend))
    elif spec.startswith("scalarq"):
        comp_obj = C.ScalarQuantCompressor(bits=8, backend=backend)
    else:
        comp_obj = C.make_compressor(spec, pq=PQ)
    z = _z()
    comp = comp_obj.compress(z)
    buf = comp_obj.wire_payload(comp, value_dtype="float32")
    dp = wire.decode_payload(buf)
    assert wire.encode_decoded(dp) == buf          # idempotent re-encode
    rec = wire.reconstruct(dp)
    assert rec.shape == (12, 64)
    np.testing.assert_allclose(rec, np.asarray(comp.recon), atol=1e-6)
    # codes/indices survive the wire exactly (the lossy steps are value
    # dtype casts only, and f32 was used above)
    if dp.kind == "sparse":
        sp = comp.payload if isinstance(comp.payload, C.SparsePayload) \
            else comp.payload[0]
        np.testing.assert_array_equal(dp.arrays["indices"],
                                      np.asarray(sp.indices))
    if dp.kind == "scalar":
        np.testing.assert_array_equal(
            dp.arrays["codes"].reshape(-1),
            np.asarray(comp.payload.codes).reshape(-1))


@pytest.mark.parametrize("spec", _all_specs())
def test_measured_bytes_track_analytic(spec):
    """len(wire_payload)*8 is within the per-stage header + CRC-trailer
    overhead of analytic_bits at the wire width."""
    c = C.make_compressor(spec, pq=PQ)
    z = _z()
    buf = c.wire_payload(c.compress(z), value_dtype="float32")
    analytic = c.analytic_bits(12, 64, phi_bits=32)
    stages = len(c.stages) if isinstance(c, C.ChainCompressor) else 1
    overhead = len(buf) * 8 - analytic
    frame = (wire.HEADER_BYTES + wire.CRC_BYTES) * 8 + 7
    assert 0 <= overhead <= stages * frame, (spec, overhead)


def test_multi_carrier_chain_roundtrip():
    """Chains with more than one carrier stage encode each stage against
    ITS OWN input geometry (regression: inner indices once used the outer
    tensor's n*d and the payload could not be decoded)."""
    c = C.make_compressor("chain:topk(k=0.5)+topk(k=0.5)")
    z = _z((8, 48), seed=7)
    comp = c.compress(z)
    buf = c.wire_payload(comp, value_dtype="float32")
    dp = wire.decode_payload(buf)
    assert dp.kind == "sparse" and dp.inner is not None
    assert dp.inner.kind == "sparse"
    assert dp.inner.n * dp.inner.d == c.stages[0].k_count(z.size)
    np.testing.assert_allclose(wire.reconstruct(dp),
                               np.asarray(comp.recon), atol=1e-6)
    assert wire.encode_decoded(dp) == buf
    # analytic accounting agrees to within the per-stage frame overhead
    overhead = len(buf) * 8 - c.analytic_bits(8, 48, 32)
    assert 0 <= overhead <= 2 * ((wire.HEADER_BYTES + wire.CRC_BYTES) * 8 + 7)


def test_chain_hits_acceptance_ratio():
    """The acceptance codec cuts the FEMNIST-cut gradient >= 8x, measured."""
    c = C.make_compressor("chain:topk(k=0.1)+scalarq(bits=8)")
    g = _z((8, 9216), seed=3)   # client_batch x cut_dim
    buf = c.wire_payload(c.compress(g))
    dense = g.size * 4
    assert dense / len(buf) >= 8.0
    # analytic model agrees
    assert 32 * g.size / c.analytic_bits(8, 9216, 32) >= 8.0


# ---------------------------------------------------------------------------
# compressor math
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_magnitudes():
    c = C.TopKCompressor(k=0.25)
    z = jnp.arange(1.0, 17.0).reshape(4, 4) * jnp.asarray([1, -1] * 8
                                                          ).reshape(4, 4)
    comp = c.compress(z)
    kept = np.flatnonzero(np.asarray(comp.recon).reshape(-1))
    assert set(kept) == {12, 13, 14, 15}        # the four largest |z|
    np.testing.assert_array_equal(
        np.asarray(comp.recon).reshape(-1)[kept],
        np.asarray(z).reshape(-1)[kept])        # survivors pass unchanged
    np.testing.assert_allclose(np.asarray(comp.recon + comp.residual),
                               np.asarray(z), rtol=1e-6)


def test_scalarq_quantization_error_bounded():
    c = C.ScalarQuantCompressor(bits=8, backend="jnp")
    z = _z((16, 32), seed=1)
    comp = c.compress(z)
    scale = float(np.asarray(comp.payload.scale))
    # nearest rounding: error <= scale/2 everywhere
    assert float(jnp.abs(comp.residual).max()) <= scale / 2 + 1e-6


def test_scalarq_stochastic_rounding_unbiased():
    """With stochastic rounding, E[recon] -> z (mean over many keys)."""
    c = C.ScalarQuantCompressor(bits=4, backend="jnp")
    z = _z((4, 16), seed=2)
    recs = [c.compress(z, key=jax.random.PRNGKey(i)).recon
            for i in range(200)]
    mean = np.mean([np.asarray(r) for r in recs], axis=0)
    scale = float(np.asarray(c.compress(z).payload.scale))
    # the empirical mean lands far inside one quantization step of z
    assert np.abs(mean - np.asarray(z)).max() < 0.2 * scale


def test_scalarq_jnp_pallas_parity():
    z = _z((16, 64), seed=4)
    a = C.ScalarQuantCompressor(bits=8, backend="jnp").compress(z)
    b = C.ScalarQuantCompressor(bits=8, backend="pallas").compress(z)
    np.testing.assert_array_equal(np.asarray(a.payload.codes),
                                  np.asarray(b.payload.codes))
    np.testing.assert_allclose(np.asarray(a.recon), np.asarray(b.recon),
                               atol=1e-6)


def test_device_pack_matches_host_stream():
    """The Pallas pack kernel writes the identical LSB-first byte stream
    the host wire codec writes (32 % bits == 0 widths)."""
    from repro.federated.wire import _pack_codes
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for bits in (2, 4, 8, 16):
        codes = rng.integers(0, 1 << bits, size=999).astype(np.int64)
        host = _pack_codes(codes.astype(np.uint32), bits)
        dev = np.asarray(ops.pack_codes(jnp.asarray(codes, jnp.int32), bits))
        assert dev.tobytes()[:len(host)] == host
        back = np.asarray(ops.unpack_codes(jnp.asarray(dev), codes.size,
                                           bits))
        np.testing.assert_array_equal(back, codes)


def test_spec_parser_and_registry():
    assert isinstance(C.make_compressor("none"), C.NoneCompressor)
    c = C.make_compressor("chain:topk(k=0.5)+scalarq(bits=4, backend=jnp)")
    assert isinstance(c, C.ChainCompressor)
    assert c.stages[0].k == 0.5 and c.stages[1].bits == 4
    assert C.make_compressor(c) is c
    assert C.make_compressor(None) is None
    with pytest.raises(ValueError):
        C.make_compressor("nosuch(k=1)")
    with pytest.raises(ValueError):
        C.make_compressor("pq")             # needs a PQConfig
    with pytest.raises(ValueError):
        C.make_compressor("chain:scalarq(bits=8)+topk(k=0.1)")  # terminal mid-chain
    with pytest.raises(ValueError):
        C.make_compressor("topk(k=1.5)")


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_accumulates_and_flushes():
    """EF invariants: (i) recon + memory' == z + memory exactly (nothing is
    lost, only delayed); (ii) over repeated rounds on a constant signal the
    cumulative transmitted mass approaches the cumulative signal."""
    ef = C.ErrorFeedback(C.TopKCompressor(k=0.125))
    z = _z((4, 16), seed=5)
    mem = ef.init_memory(z)
    sent = jnp.zeros_like(z)
    for _ in range(12):
        comp, new_mem = ef.step(z, mem)
        np.testing.assert_allclose(np.asarray(comp.recon + new_mem),
                                   np.asarray(z + mem), rtol=1e-5,
                                   atol=1e-6)
        mem = new_mem
        sent = sent + comp.recon
    # telescoping: cumulative transmitted + residual memory == cumulative
    # signal, exactly — compression only DELAYS mass, never loses it
    np.testing.assert_allclose(np.asarray(sent + mem), np.asarray(12.0 * z),
                               rtol=1e-4, atol=1e-5)
    # and the memory stays bounded: far below one round's worth per 12
    assert float(jnp.abs(mem).max()) < 12 * float(jnp.abs(z).max())


def test_error_feedback_identity_for_none():
    ef = C.ErrorFeedback(C.NoneCompressor())
    z = _z((2, 8))
    comp, mem = ef.step(z, ef.init_memory(z))
    np.testing.assert_array_equal(np.asarray(comp.recon), np.asarray(z))
    np.testing.assert_array_equal(np.asarray(mem), np.zeros_like(z))


# ---------------------------------------------------------------------------
# VJP hooks
# ---------------------------------------------------------------------------

def test_downlink_none_is_bitwise_identity():
    """downlink_compressor="none" reproduces the uncompressed backward pass
    bit for bit — the acceptance-criteria equivalence."""
    cn = C.NoneCompressor()
    z = _z((6, 32))

    def f_hooked(x):
        return jnp.sum(jnp.sin(C.compress_downlink(x, cn)) ** 2)

    def f_plain(x):
        return jnp.sum(jnp.sin(x) ** 2)

    g_h = jax.grad(f_hooked)(z)
    g_p = jax.grad(f_plain)(z)
    np.testing.assert_array_equal(np.asarray(g_h), np.asarray(g_p))


def test_downlink_compresses_cotangent_only():
    """Forward values are untouched; the backward cotangent is sparsified."""
    c = C.TopKCompressor(k=0.1)
    z = _z((4, 64))
    out, vjp = jax.vjp(lambda x: C.compress_downlink(x, c), z)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z))
    g = jax.random.normal(jax.random.PRNGKey(1), z.shape)
    (gz,) = vjp(g)
    nz = int(jnp.sum(gz != 0))
    assert nz == c.k_count(z.size)
    # surviving entries pass through unchanged
    mask = np.asarray(gz != 0)
    np.testing.assert_allclose(np.asarray(gz)[mask], np.asarray(g)[mask],
                               rtol=1e-6)


def test_compress_with_correction_matches_pq_path():
    """The generic uplink hook over PQCompressor == the specialized
    quantize_with_correction (same fused residual, same λ-corrected VJP)."""
    from repro.core.correction import quantize_with_correction
    z = _z((10, 64), seed=6)
    pqc = C.PQCompressor(cfg=PQ)

    def loss_generic(x):
        return jnp.sum(C.compress_with_correction(x, 0.3, pqc) ** 2)

    def loss_pq(x):
        return jnp.sum(quantize_with_correction(x, 0.3, PQ) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(loss_generic)(z)),
                               np.asarray(jax.grad(loss_pq)(z)),
                               rtol=1e-6, atol=1e-7)


def test_model_downlink_none_bitwise_grads():
    """FemnistCNN: grads with downlink "none" == grads with no downlink."""
    from repro.models.paper_models import FemnistCNN
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    m0 = FemnistCNN(pq=pq, lam=1e-4)
    m1 = FemnistCNN(pq=pq, lam=1e-4,
                    downlink_compressor=C.make_compressor("none"))
    params = m0.init(jax.random.PRNGKey(0))
    batch = {"image": jax.random.normal(jax.random.PRNGKey(1),
                                        (8, 28, 28, 1)),
             "label": jnp.zeros((8,), jnp.int32)}
    g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_downlink_chain_touches_client_grads_only():
    """A lossy downlink codec changes CLIENT grads (they live below the
    cut) but leaves server grads bit-identical (they live above it)."""
    from repro.models.paper_models import FemnistCNN
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    dl = C.make_compressor("chain:topk(k=0.1)+scalarq(bits=8)")
    m0 = FemnistCNN(pq=pq, lam=1e-4)
    m1 = FemnistCNN(pq=pq, lam=1e-4, downlink_compressor=dl)
    params = m0.init(jax.random.PRNGKey(0))
    batch = {"image": jax.random.normal(jax.random.PRNGKey(1),
                                        (8, 28, 28, 1)),
             "label": jnp.zeros((8,), jnp.int32)}
    g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0["server"]),
                    jax.tree.leaves(g1["server"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    diffs = [float(jnp.abs(a - b).max()) for a, b in
             zip(jax.tree.leaves(g0["client"]),
                 jax.tree.leaves(g1["client"]))]
    assert max(diffs) > 0
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(g1))


def test_transformer_downlink_spec_via_arch_config():
    """ArchConfig.downlink_compressor reaches the LM's cut layer."""
    import dataclasses as dc
    from repro.configs.base import get_arch
    from repro.data.synthetic import make_lm_batch
    from repro.launch.specs import make_model
    cfg = dc.replace(get_arch("llama3_8b", smoke=True),
                     downlink_compressor="chain:topk(k=0.1)+scalarq(bits=8)")
    model = make_model(cfg)
    assert model.downlink_compressor is not None
    params = model.init(jax.random.PRNGKey(0))
    batch = make_lm_batch(jax.random.PRNGKey(1), 2, 32, cfg.vocab_size)
    (loss, metrics), g = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert metrics["downlink_message_bits"] > 0
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# runtime integration: measured downlink + per-contribution staleness
# ---------------------------------------------------------------------------

def _trainer(**kw):
    from repro.data.synthetic import make_federated_image_data
    from repro.federated import FederatedTrainer
    from repro.models.paper_models import FemnistCNN
    from repro.optim import sgd
    data = make_federated_image_data(num_clients=8, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    return FederatedTrainer(model, sgd(0.03), data, cohort=4, client_batch=8,
                            **kw)


def test_trainer_measures_compressed_downlink():
    tr = _trainer(downlink_compressor="chain:topk(k=0.1)+scalarq(bits=8)")
    state = tr.init_state(jax.random.PRNGKey(0))
    up, down = tr.measure_round_bytes(state, jax.random.PRNGKey(1))
    dense = tr.measure_dense_bytes(state, jax.random.PRNGKey(1))
    assert dense / down >= 8.0          # the acceptance reduction, measured
    assert tr.model.downlink_compressor is not None   # installed in the VJP


def test_trainer_downlink_none_bitwise_trajectory():
    key = jax.random.PRNGKey(0)
    s1, _ = _trainer(downlink_compressor="none").run(3, key)
    s2, _ = _trainer().run(3, key)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_downlink_chain_still_trains():
    tr = _trainer(downlink_compressor="chain:topk(k=0.3)+scalarq(bits=8)")
    _, hist = tr.run(6, jax.random.PRNGKey(0))
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses).all()
    assert min(losses[1:]) < losses[0]
    assert tr.last_trace.meta["downlink_compressor"] == \
        "chain:topk(k=0.3)+scalarq(bits=8)"
    rec = tr.last_trace.records[0]
    assert rec.downlink_bytes < rec.uplink_bytes * 100   # sanity: measured


def test_per_contribution_staleness_weighting():
    """AsyncBuffer: the weighted step discounts each contribution by its
    own staleness — verified against a hand-rolled per-client computation."""
    from repro.core.fedlite import TrainState, make_weighted_step
    from repro.models.paper_models import FemnistCNN
    from repro.optim import sgd
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    opt = sgd(0.1)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState.create(params, opt)
    batches = {
        "image": jax.random.normal(jax.random.PRNGKey(1), (2, 4, 28, 28, 1)),
        "label": jnp.zeros((2, 4), jnp.int32),
    }
    weights = jnp.asarray([1.0, 0.25])
    # donate=False: the manual check below reuses the pre-step params
    step = make_weighted_step(model, opt, donate=False)
    new_state, metrics = step(state, batches, weights)

    # hand-rolled: per-client grads, FedBuff mean of w_i * g_i, one SGD step
    def one(b):
        return jax.grad(lambda p: model.loss(p, b)[0])(params)

    g0 = one({"image": batches["image"][0], "label": batches["label"][0]})
    g1 = one({"image": batches["image"][1], "label": batches["label"][1]})
    expect = jax.tree.map(lambda a, b: (1.0 * a + 0.25 * b) / 2.0, g0, g1)
    manual = jax.tree.map(lambda p, g: p - 0.1 * g, params, expect)
    for a, b in zip(jax.tree.leaves(new_state.params),
                    jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_async_run_uses_per_contribution_weights():
    from repro.federated import AsyncBuffer
    tr = _trainer(policy=AsyncBuffer(2))
    _, hist = tr.run(4, jax.random.PRNGKey(0))
    assert all(np.isfinite(h["loss"]) for h in hist)
    # at least one flush mixed stalenesses -> the weighted path ran
    stale = [r.staleness for r in tr.last_trace]
    assert any(len(set(s)) >= 1 for s in stale)


# ---------------------------------------------------------------------------
# cross-round cut-layer state in the trainer (PR 4)
# ---------------------------------------------------------------------------

def test_trainer_warm_start_carries_codebooks_across_rounds():
    tr = _trainer(warm_start=True)
    _, hist = tr.run(3, jax.random.PRNGKey(0))
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert tr._global_q is not None
    assert int(tr._global_q.rounds) == 3          # one warm lineage
    assert tr.last_trace.meta["warm_start"] is True
    # history metrics stay scalar: the cut state was popped before logging
    assert all("cut_state" not in h for h in hist)


def test_trainer_error_feedback_carries_memory_across_rounds():
    tr = _trainer(error_feedback=True)
    _, hist = tr.run(3, jax.random.PRNGKey(0))
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert len(tr._ef_memory) > 0                 # per-client slots populated
    mem = next(iter(tr._ef_memory.values()))
    assert mem.shape == (8, 9216)                 # client_batch x cut dim
    assert float(jnp.abs(mem).max()) > 0.0        # PQ is lossy: error nonzero


def test_trainer_async_warm_start_per_client_slots():
    from repro.federated import AsyncBuffer
    tr = _trainer(warm_start=True, error_feedback=True,
                  policy=AsyncBuffer(2))
    _, hist = tr.run(4, jax.random.PRNGKey(0))
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert len(tr._client_q) > 0                  # per-client codebooks
    q = next(iter(tr._client_q.values()))
    assert q.codebooks.shape == (1, 4, 32)        # (R, L, d/q) for q=288


def test_trainer_codebook_delta_measured_bytes():
    tr = _trainer(codebook_delta_bits=8)
    state = tr.init_state(jax.random.PRNGKey(0))
    up, _ = tr.measure_round_bytes(state, jax.random.PRNGKey(1))
    meta = tr.last_codebook_meta
    assert up == meta["uplink_bytes_delta_codebook"]
    assert meta["codebook_bytes_delta"] < meta["codebook_bytes_full"]
    assert meta["codebook_bytes_reduction"] > 1.0


def test_trainer_rejects_bad_cut_state_configs():
    with pytest.raises(ValueError, match="pq uplink"):
        _trainer(warm_start=True, uplink_compressor="none", quantize=False)
    with pytest.raises(ValueError, match="quantize"):
        _trainer(error_feedback=True, quantize=False,
                 uplink_compressor="none")
    with pytest.raises(ValueError, match="codebook_delta_bits"):
        _trainer(codebook_delta_bits=99)


def test_stochastic_downlink_key_changes_gradients_not_keyless_path():
    """A step_key makes the scalarq downlink round stochastically (grads
    differ across keys); the keyless step stays bitwise-identical to the
    historical deterministic path."""
    from repro.core.fedlite import TrainState, make_train_step
    from repro.models.paper_models import FemnistCNN
    from repro.optim import sgd
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4,
                       downlink_compressor=C.make_compressor(
                           "scalarq(bits=4)"))
    opt = sgd(0.1)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    batch = {"image": jax.random.normal(jax.random.PRNGKey(1),
                                        (4, 28, 28, 1)),
             "label": jnp.zeros((4,), jnp.int32)}
    plain = make_train_step(model, opt, donate=False)
    s_a, _ = plain(state, batch)
    s_b, _ = plain(state, batch)
    for a, b in zip(jax.tree.leaves(s_a.params), jax.tree.leaves(s_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    keyed1 = make_train_step(model, opt, donate=False,
                             step_key=jax.random.PRNGKey(7))
    keyed2 = make_train_step(model, opt, donate=False,
                             step_key=jax.random.PRNGKey(8))
    k1, _ = keyed1(state, batch)
    k2, _ = keyed2(state, batch)
    diffs = [bool(jnp.any(a != b)) for a, b in
             zip(jax.tree.leaves(k1.params), jax.tree.leaves(k2.params))]
    assert any(diffs)                             # stochastic rounding bites


def test_trainer_warm_start_stacked_state_cold_falls_back_on_cohort_change():
    """Per-client/per-row stacked quantizer state (codebooks rank > 3 —
    TransformerLM per-sequence vmap, paper models with client_batch > 0)
    only fits a cohort of the size that produced it: a different
    participant count must fall back to a cold round instead of vmapping
    mismatched axes."""
    from repro.core.quantizer import QuantizerState
    from repro.federated.scheduler import Arrival

    tr = _trainer(warm_start=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    part = tr.client_batch_for(0, jax.random.PRNGKey(1))
    arr = lambda cid: Arrival(client=cid, version=0, t_arrival=0.0)

    # cohort-size-independent state (rank 3): reused across any count
    tr._global_q = QuantizerState(codebooks=jnp.zeros((1, 4, 32)),
                                  rounds=jnp.ones((), jnp.int32))
    tr._global_q_nparts = 4
    cs = tr._cut_state_for([arr(0), arr(1)], state.params, [part],
                           stacked=True)
    assert cs.quantizer is not None
    # stacked state (rank 4, one slot per client/row): count change -> cold
    tr._global_q = QuantizerState(codebooks=jnp.zeros((4, 1, 4, 32)),
                                  rounds=jnp.ones((4,), jnp.int32))
    tr._global_q_nparts = 4
    cs = tr._cut_state_for([arr(0), arr(1)], state.params, [part],
                           stacked=True)
    assert cs.quantizer is None
    cs = tr._cut_state_for([arr(0), arr(1), arr(2), arr(3)], state.params,
                           [part], stacked=True)
    assert cs.quantizer is not None
