"""Tests for the bench-regression sentinel (`benchmarks/sentinel.py`) and
the append-only bench history (`benchmarks/common.append_bench_history`).

The sentinel is a pure-stdlib comparator so CI can run it without the
pinned scientific stack; these tests exercise it the same way — no jax.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import append_bench_history          # noqa: E402
from benchmarks.sentinel import (compare, inject_regression,  # noqa: E402
                                 load_baseline, load_current, main,
                                 metric_tolerance)


def _write_snapshot(root, suite, rows):
    doc = {"suite": suite, "rows": rows}
    (root / f"BENCH_{suite}.json").write_text(json.dumps(doc))


def _baseline_doc(rows):
    return {"note": "test baseline", "rows": rows}


# ---------------------------------------------------------------------------
# tolerance classes
# ---------------------------------------------------------------------------

def test_metric_tolerance_classes():
    # host-noise metrics: tracked, never gated
    for noisy in ("us_per_call", "wall_s", "rss_mb", "setup_s", "speedup_x",
                  "s_per_round_flights_on", "overhead_x"):
        assert metric_tolerance(noisy) is None
    # stochastic-but-seeded training metrics: wide gate
    assert metric_tolerance("loss") == 0.25
    assert metric_tolerance("final_loss") == 0.25
    # everything else is deterministic sim output: tight gate
    assert metric_tolerance("uplink_bytes") == 0.01
    assert metric_tolerance("quarantine_rate") == 0.01
    # "overhead" alone is NOT noise: byte-overhead ratios stay gated
    assert metric_tolerance("retry_byte_overhead") == 0.01
    assert metric_tolerance("header_overhead_bits") == 0.01


# ---------------------------------------------------------------------------
# compare(): deltas, flags, untracked/missing bookkeeping
# ---------------------------------------------------------------------------

def test_compare_flags_only_gated_regressions():
    base = {"net/cell": {"uplink_bytes": 1000.0, "wall_s": 2.0}}
    cur = {"net/cell": {"uplink_bytes": 1000.0, "wall_s": 9.0}}
    deltas, untracked, missing = compare(base, cur)
    assert untracked == [] and missing == []
    flagged = [d for d in deltas if d["flagged"]]
    assert flagged == []                       # wall-clock never gates
    cur = {"net/cell": {"uplink_bytes": 1030.0, "wall_s": 2.0}}
    deltas, _, _ = compare(base, cur)
    (bad,) = [d for d in deltas if d["flagged"]]
    assert bad["metric"] == "uplink_bytes"
    assert bad["rel"] == pytest.approx(0.03)
    assert bad["tol"] == 0.01 and bad["gated"]


def test_compare_within_tolerance_is_clean():
    base = {"net/cell": {"loss": 1.00, "uplink_bytes": 1000.0}}
    cur = {"net/cell": {"loss": 1.20, "uplink_bytes": 1005.0}}
    deltas, _, _ = compare(base, cur)
    assert all(not d["flagged"] for d in deltas)   # 20% < 25%, 0.5% < 1%


def test_compare_reports_untracked_and_missing_rows():
    base = {"net/old": {"x": 1.0}, "net/both": {"x": 1.0}}
    cur = {"net/new": {"x": 1.0}, "net/both": {"x": 1.0}}
    deltas, untracked, missing = compare(base, cur)
    assert [d["key"] for d in deltas] == ["net/both"] or \
        all(d["key"] == "net/both" for d in deltas)
    assert untracked == ["net/new"]            # current-only: needs update
    assert missing == ["net/old"]              # baseline-only: bench vanished


def test_compare_zero_baseline_still_gates_movement():
    # a zero baseline can't use a relative denominator; any real movement
    # away from 0 must still flag (tiny-epsilon denominator)
    base = {"s/r": {"drop_rate": 0.0}}
    same, _, _ = compare(base, {"s/r": {"drop_rate": 0.0}})
    assert all(not d["flagged"] for d in same)
    moved, _, _ = compare(base, {"s/r": {"drop_rate": 0.5}})
    assert any(d["flagged"] for d in moved)


def test_inject_regression_perturbs_one_gated_metric():
    cur = {"net/cell": {"wall_s": 2.0, "uplink_bytes": 1000.0}}
    mutated = json.loads(json.dumps(cur))
    where = inject_regression(mutated)         # mutates in place
    assert where == "net/cell:uplink_bytes"    # never the ungated wall_s
    deltas, _, _ = compare(cur, mutated)
    assert sum(d["flagged"] for d in deltas) == 1
    (bad,) = [d for d in deltas if d["flagged"]]
    assert bad["metric"] == "uplink_bytes"


# ---------------------------------------------------------------------------
# CLI: update -> check round trip against a scratch repo root
# ---------------------------------------------------------------------------

def _scratch_repo(tmp_path):
    _write_snapshot(tmp_path, "net", [
        {"name": "cell_a", "uplink_bytes": 1000.0, "wall_s": 2.0},
        {"name": "cell_b", "loss": 1.5},
    ])
    return tmp_path


def test_check_without_baseline_exits_2(tmp_path, capsys):
    root = _scratch_repo(tmp_path)
    code = main(["check", "--root", str(root),
                 "--baseline", str(root / "baseline.json")])
    assert code == 2
    assert "no baseline" in capsys.readouterr().err


def test_update_then_check_is_green(tmp_path, capsys):
    root = _scratch_repo(tmp_path)
    baseline = root / "baseline.json"
    assert main(["update", "--root", str(root),
                 "--baseline", str(baseline)]) == 0
    doc = json.loads(baseline.read_text())
    assert set(doc["rows"]) == {"net/cell_a", "net/cell_b"}
    assert main(["check", "--root", str(root),
                 "--baseline", str(baseline)]) == 0
    assert "0 regression" in capsys.readouterr().out


def test_check_flags_a_real_regression(tmp_path, capsys):
    root = _scratch_repo(tmp_path)
    baseline = root / "baseline.json"
    main(["update", "--root", str(root), "--baseline", str(baseline)])
    _write_snapshot(root, "net", [
        {"name": "cell_a", "uplink_bytes": 1100.0, "wall_s": 99.0},
        {"name": "cell_b", "loss": 1.5},
    ])
    assert main(["check", "--root", str(root),
                 "--baseline", str(baseline)]) == 1
    out = capsys.readouterr().out
    regression_lines = [l for l in out.splitlines()
                        if l.startswith("REGRESSION")]
    assert len(regression_lines) == 1          # wall_s 50x move: not gated
    assert "uplink_bytes" in regression_lines[0]


def test_check_inject_regression_goes_red(tmp_path, capsys):
    root = _scratch_repo(tmp_path)
    baseline = root / "baseline.json"
    main(["update", "--root", str(root), "--baseline", str(baseline)])
    assert main(["check", "--inject-regression", "--root", str(root),
                 "--baseline", str(baseline)]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_load_current_skips_docs_without_rows(tmp_path):
    (tmp_path / "BENCH_weird.json").write_text(json.dumps({"note": "hi"}))
    _write_snapshot(tmp_path, "ok", [{"name": "r", "x": 1.0}])
    cur = load_current(tmp_path)
    assert set(cur) == {"ok/r"}


def test_load_baseline_missing_raises(tmp_path):
    with pytest.raises(OSError):
        load_baseline(tmp_path / "nope.json")


# ---------------------------------------------------------------------------
# append-only bench history
# ---------------------------------------------------------------------------

def test_append_bench_history_is_append_only_jsonl(tmp_path):
    hist = tmp_path / "hist.jsonl"
    rows = [{"name": "cell_a", "uplink_bytes": 1000.0, "note": "text",
             "flag": True}]
    append_bench_history("net", rows, path=hist)
    append_bench_history("net", rows, path=hist)
    lines = hist.read_text().splitlines()
    assert len(lines) == 2                     # appended, not rewritten
    doc = json.loads(lines[0])
    assert doc["suite"] == "net" and doc["name"] == "cell_a"
    assert isinstance(doc["sha"], str) and doc["sha"]
    # only numeric (non-bool) metrics ride along
    assert doc["metrics"] == {"uplink_bytes": 1000.0}
