"""K-means unit + property tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt); without it
the property tests skip instead of aborting collection.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import kmeans as km


def test_trivial_two_clusters():
    x = jnp.array([[0.0], [0.1], [0.05], [5.0], [5.1], [5.05]])
    r = km.kmeans(x, 2, 10)
    assert float(r.distortion) < 0.01
    c = np.sort(np.asarray(r.centroids).ravel())
    np.testing.assert_allclose(c, [0.05, 5.05], atol=0.01)


def test_recovers_well_separated_clusters():
    key = jax.random.PRNGKey(0)
    centers = jax.random.normal(key, (4, 32)) * 5
    assign = jax.random.randint(jax.random.PRNGKey(1), (256,), 0, 4)
    z = centers[assign] + 0.01 * jax.random.normal(jax.random.PRNGKey(2),
                                                   (256, 32))
    r = km.kmeans(z, 4, 25)
    assert float(r.distortion) < 0.05


def test_chunking_invariance():
    x = jax.random.normal(jax.random.PRNGKey(3), (1000, 8))
    r1 = km.kmeans(x, 8, 5, chunk=1000)
    r2 = km.kmeans(x, 8, 5, chunk=128)
    np.testing.assert_allclose(r1.centroids, r2.centroids, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_array_equal(r1.codes, r2.codes)


def test_batched_kmeans_independent_groups():
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 200, 8))
    cents, codes, dist = km.batched_kmeans(x, 4, 6)
    assert cents.shape == (3, 4, 8) and codes.shape == (3, 200)
    for g in range(3):
        r = km.kmeans(x[g], 4, 6)
        np.testing.assert_allclose(cents[g], r.centroids, rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(8, 200), d=st.integers(1, 16), L=st.integers(1, 8),
           iters=st.integers(1, 6))
    def test_property_distortion_nonincreasing_in_L(n, d, L, iters):
        """More clusters never hurt (same seeding scheme): dist(L+1) <=
        ~dist(L); and distortion is finite/nonnegative."""
        x = jax.random.normal(jax.random.PRNGKey(n + d), (n, d))
        r = km.kmeans(x, L, iters)
        assert float(r.distortion) >= 0 and np.isfinite(float(r.distortion))
        assert int(r.codes.max()) < L
        r2 = km.kmeans(x, min(L + 4, n), iters)
        assert float(r2.distortion) <= float(r.distortion) * 1.05 + 1e-4
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_property_distortion_nonincreasing_in_L():
        pass


def test_exact_cover_is_fixed_point():
    """Clusters whose members all equal the centroid must reconstruct
    EXACTLY (deviation-accumulated Lloyd update) — the FedLite -> SplitFed
    gradient equivalence depends on a bitwise-zero residual here."""
    proto = jax.random.normal(jax.random.PRNGKey(11), (2, 64))
    x = jnp.concatenate([jnp.tile(proto[0], (8, 1)),
                         jnp.tile(proto[1], (8, 1))])
    r = km.kmeans(x, 2, 8)
    np.testing.assert_array_equal(np.asarray(r.centroids[r.codes]),
                                  np.asarray(x))
    assert float(r.distortion) == 0.0


def test_works_under_jit_grad_context():
    """kmeans is used inside custom_vjp forwards — must trace cleanly."""
    x = jax.random.normal(jax.random.PRNGKey(7), (64, 8))

    @jax.jit
    def f(x):
        r = km.kmeans(x, 4, 3)
        return r.distortion

    assert np.isfinite(float(f(x)))
