"""Wire codec tests: bit-exact round-trips and measured-vs-analytic bytes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import PQConfig, QuantizedBatch, quantize
from repro.federated import wire


def _qb(backend="jnp", q=8, L=5, r=1, n=24, d=64, seed=0):
    cfg = PQConfig(num_subvectors=q, num_clusters=L, num_groups=r,
                   kmeans_iters=3, backend=backend)
    z = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return quantize(z, cfg), cfg, z


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_roundtrip_bit_exact(backend):
    """decode(encode(qb)) reproduces codes/codebooks/z̃ exactly at fp32."""
    qb, cfg, _ = _qb(backend=backend)
    buf = wire.encode_bytes(qb, "float32")
    wb = wire.decode_bytes(buf)
    np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))
    np.testing.assert_array_equal(wb.codebooks, np.asarray(qb.codebooks))
    # server-side reconstruction == the training-path dequantized batch
    np.testing.assert_array_equal(wire.dequantize(wb),
                                  np.asarray(qb.dequantized))


def test_roundtrip_idempotent_bytes():
    """Re-encoding a decoded payload is byte-identical (codec is lossless)."""
    qb, cfg, _ = _qb()
    buf = wire.encode_bytes(qb, "float16")
    wb = wire.decode_bytes(buf)
    qb2 = QuantizedBatch(
        dequantized=jnp.asarray(wire.dequantize(wb).astype(np.float32)),
        codes=jnp.asarray(wb.codes),
        codebooks=jnp.asarray(np.asarray(wb.codebooks)),
        distortion=qb.distortion, residual=qb.residual)
    assert wire.encode_bytes(qb2, "float16") == buf


def test_fp16_codebooks_are_exact_cast():
    qb, cfg, _ = _qb()
    wb = wire.decode_bytes(wire.encode_bytes(qb, "float16"))
    np.testing.assert_array_equal(
        wb.codebooks, np.asarray(qb.codebooks).astype(np.float16))
    np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))


@pytest.mark.parametrize("q,L,r,n,d", [
    (8, 5, 1, 24, 64),      # paper default R=1
    (8, 4, 4, 16, 64),      # grouped codebooks R>1
    (1, 7, 1, 32, 16),      # whole-vector K-means
    (4, 1, 1, 10, 32),      # L=1: codebook only, zero code bits
    (16, 256, 2, 12, 64),   # 8-bit codes (byte-aligned)
    (6, 3, 3, 9, 48),       # non-power-of-two L, odd sizes
])
def test_measured_bytes_match_analytic(q, L, r, n, d):
    """len(encode_bytes) == wire_bits exactly, and wire_bits is within the
    documented header overhead of PQConfig.message_bits at the wire φ."""
    cfg = PQConfig(num_subvectors=q, num_clusters=L, num_groups=r,
                   kmeans_iters=2)
    z = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    qb = quantize(z, cfg)
    buf = wire.encode_bytes(qb, "float16")
    assert len(buf) * 8 == wire.wire_bits(cfg, n, d, "float16")
    overhead = wire.wire_bits(cfg, n, d, "float16") \
        - cfg.message_bits(n, d, phi_bits=16)
    # header + CRC trailer + sub-byte padding of the code stream, no more
    assert 0 <= overhead <= (wire.HEADER_BYTES + wire.CRC_BYTES) * 8 + 7


def test_multidim_leading_shape():
    """(B, S, d) activations flatten to n=B*S vectors on the wire."""
    cfg = PQConfig(num_subvectors=4, num_clusters=4, kmeans_iters=2)
    z = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 32))
    qb = quantize(z, cfg)
    wb = wire.decode_bytes(wire.encode_bytes(qb, "float32"))
    assert wb.n == 15 and wb.d == 32
    np.testing.assert_array_equal(
        wire.dequantize(wb), np.asarray(qb.dequantized).reshape(15, 32))


def test_bits_per_code_metadata():
    assert PQConfig(num_subvectors=1, num_clusters=1).bits_per_code == 0
    assert PQConfig(num_subvectors=1, num_clusters=2).bits_per_code == 1
    assert PQConfig(num_subvectors=1, num_clusters=5).bits_per_code == 3
    assert PQConfig(num_subvectors=1, num_clusters=256).bits_per_code == 8
    cfg = PQConfig(num_subvectors=8, num_clusters=16, num_groups=2)
    assert cfg.codebook_shape(64) == (2, 16, 8)
    assert cfg.num_codes(10) == 80
    # codes_bits stays consistent with the metadata it is derived from
    assert cfg.codes_bits(10) == 80 * 4


def test_decode_rejects_garbage():
    with pytest.raises(ValueError):
        wire.decode_bytes(b"nope")
    qb, _, _ = _qb()
    buf = wire.encode_bytes(qb)
    with pytest.raises(ValueError):
        wire.decode_bytes(b"XXXX" + buf[4:])       # bad magic
    with pytest.raises(ValueError):
        wire.decode_bytes(buf[:-1])                # truncated


def test_unknown_version_rejected_with_clear_error():
    """A stale/foreign payload must fail loudly, not decode as garbage."""
    qb, _, _ = _qb()
    buf = bytearray(wire.encode_bytes(qb))
    assert buf[4] == 4                              # current format version
    buf[4] = 7                                      # a future/stale version
    with pytest.raises(wire.WireVersionError, match="version 7"):
        wire.decode_bytes(bytes(buf))
    with pytest.raises(wire.WireVersionError, match="version 7"):
        wire.decode_payload(bytes(buf))


def test_version1_payloads_still_decode():
    """The PR 2 codec (version 1, zero flags byte) remains readable."""
    qb, _, _ = _qb()
    buf = wire._legacy_frame(wire.encode_bytes(qb, "float16"), 1)
    assert buf[4] == 1 and len(buf) == len(wire.encode_bytes(qb, "float16")) \
        - wire.CRC_BYTES
    wb = wire.decode_bytes(buf)
    np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))


def test_unknown_kind_rejected():
    qb, _, _ = _qb()
    buf = bytearray(wire.encode_bytes(qb))
    buf[7] = 9                                      # kind byte: unknown tag
    with pytest.raises(ValueError, match="kind"):
        wire.decode_payload(bytes(buf))
    # version 1 never carried a non-pq kind either
    buf[4] = 1
    buf[7] = wire.KIND_SPARSE
    with pytest.raises(ValueError, match="version-1"):
        wire.decode_payload(bytes(buf))


def test_pq_decode_refuses_other_kinds():
    dense = wire.encode_dense(np.zeros((4, 8), np.float32), 4, 8)
    with pytest.raises(ValueError, match="pq payload"):
        wire.decode_bytes(dense)
    dp = wire.decode_payload(dense)                 # the tagged API decodes it
    assert dp.kind == "dense" and dp.n == 4 and dp.d == 8


# ---------------------------------------------------------------------------
# pq-delta (cross-round codebook reuse; version-gated v3)
# ---------------------------------------------------------------------------

def _delta_pair(delta_bits=8):
    """Two consecutive rounds: (round-1 batch, acked round-0 reference)."""
    from repro.core.quantizer import quantize_stateful
    cfg = PQConfig(num_subvectors=8, num_clusters=16, kmeans_iters=3)
    z1 = jax.random.normal(jax.random.PRNGKey(30), (24, 64))
    z2 = z1 + 0.05 * jax.random.normal(jax.random.PRNGKey(31), (24, 64))
    qb1, st = quantize_stateful(z1, cfg)
    ref = wire.decode_bytes(wire.encode_bytes(qb1, "float16")) \
        .codebooks.astype(np.float32)
    qb2, _ = quantize_stateful(z2, cfg, st)
    return cfg, qb2, ref


@pytest.mark.parametrize("delta_bits", [4, 8])
def test_pq_delta_roundtrip_bit_exact(delta_bits):
    """decode_pq_delta(encode_pq_delta(...)) reproduces the cluster codes
    exactly and the codebooks bit-exactly equal to the encoder's closed-loop
    reconstruction (both sides adopt the same acked reference)."""
    cfg, qb, ref = _delta_pair(delta_bits)
    payload, recon = wire.encode_pq_delta(qb, ref, delta_bits)
    wb = wire.decode_pq_delta(payload, ref)
    np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))
    np.testing.assert_array_equal(wb.codebooks, recon)
    assert wb.codebooks.dtype == np.float32
    # analytic size agrees exactly with the measured payload
    assert len(payload) * 8 == wire.pq_delta_wire_bits(cfg, 24, 64,
                                                       delta_bits)


def test_pq_delta_smaller_than_full_codebooks():
    cfg, qb, ref = _delta_pair()
    payload, _ = wire.encode_pq_delta(qb, ref, 8)
    full = wire.encode_bytes(qb, "float16")
    cb_full = int(np.prod(cfg.codebook_shape(64))) * 2
    code_bytes = len(full) - wire.HEADER_BYTES - cb_full
    cb_delta = len(payload) - wire.HEADER_BYTES - code_bytes
    assert cb_full / cb_delta >= 1.5


def test_pq_delta_version_gated():
    """pq-delta was introduced at wire version 3; a v2 header with the
    pq-delta kind is a protocol violation and must be rejected."""
    cfg, qb, ref = _delta_pair()
    payload, _ = wire.encode_pq_delta(qb, ref, 8)
    assert payload[4] == 4                      # written at current version
    buf = bytearray(wire._legacy_frame(payload, 3))
    buf[4] = 2
    with pytest.raises(wire.WireVersionError, match="version >= 3"):
        wire.decode_pq_delta(bytes(buf), ref)


def test_pq_delta_needs_reference():
    cfg, qb, ref = _delta_pair()
    payload, _ = wire.encode_pq_delta(qb, ref, 8)
    with pytest.raises(ValueError, match="decode_pq_delta"):
        wire.decode_payload(payload)            # not self-describing
    with pytest.raises(ValueError, match="reference"):
        wire.decode_pq_delta(payload, ref[:, :1])   # wrong geometry
    with pytest.raises(ValueError, match="pq-delta"):
        wire.decode_pq_delta(wire.encode_bytes(qb, "float16"), ref)


def test_v2_payloads_still_decode_after_v3():
    """Legacy decode compatibility: every v2/v3 frame (no CRC trailer, no
    pq-delta epoch word) still decodes bit-exactly after the v4 bump."""
    qb, cfg, _ = _qb()
    for version in (2, 3):
        buf = wire._legacy_frame(wire.encode_bytes(qb, "float16"), version)
        assert buf[4] == version
        wb = wire.decode_bytes(buf)
        np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))
        dense = wire._legacy_frame(
            wire.encode_dense(np.zeros((4, 8), np.float32), 4, 8), version)
        assert dense[4] == version
        assert wire.decode_payload(dense).kind == "dense"


def test_v3_pq_delta_frames_still_decode():
    """A v3 pq-delta body (no epoch word) decodes bit-identically to the
    v4 frame it was downgraded from; the epoch check is skipped."""
    cfg, qb, ref = _delta_pair()
    payload, recon = wire.encode_pq_delta(qb, ref, 8, epoch=9)
    legacy = wire._legacy_frame(payload, 3)
    assert len(legacy) == len(payload) - wire.CRC_BYTES * 2  # CRC + epoch
    wb = wire.decode_pq_delta(legacy, ref, expected_epoch=3)  # ignored: v3
    np.testing.assert_array_equal(wb.codebooks, recon)
    np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))


# ---------------------------------------------------------------------------
# v4: CRC32 trailer, typed WireError hierarchy, pq-delta lineage epoch
# ---------------------------------------------------------------------------

def _sample_payloads():
    """One valid payload of every wire kind (all at the current version)."""
    qb, cfg, _ = _qb()
    _, qb_delta, ref = _delta_pair()
    delta, _ = wire.encode_pq_delta(qb_delta, ref, 8, epoch=1)
    scalar = wire.encode_scalar(np.arange(32).reshape(4, 8) % 4, -1.0, 0.5,
                                2, 4, 8)
    nested = wire.encode_sparse(np.array([1, 5, 9]), 4, 8, inner=scalar)
    return [
        ("pq", wire.encode_bytes(qb, "float16"), wire.decode_payload),
        ("dense", wire.encode_dense(np.ones((4, 8), np.float32), 4, 8),
         wire.decode_payload),
        ("sparse", wire.encode_sparse(np.array([0, 3, 17]), 4, 8,
                                      values=np.array([1., 2., 3.])),
         wire.decode_payload),
        ("sparse-nested", nested, wire.decode_payload),
        ("scalar", scalar, wire.decode_payload),
        ("pq-delta", delta,
         lambda p: wire.decode_pq_delta(p, ref, expected_epoch=1)),
    ]


def test_crc_detects_any_single_bitflip():
    """Every single-bit flip of a v4 frame raises a typed WireError —
    the CRC trailer leaves no silently-corruptible byte."""
    rng = np.random.default_rng(7)
    for name, payload, decode in _sample_payloads():
        positions = rng.choice(len(payload) * 8,
                               size=min(192, len(payload) * 8),
                               replace=False)
        for bitpos in positions:
            buf = bytearray(payload)
            buf[bitpos // 8] ^= 1 << (bitpos % 8)
            with pytest.raises(wire.WireError):
                decode(bytes(buf))


def test_truncation_always_typed_error():
    """Any truncation of any kind × any supported version raises a typed
    WireError — never an IndexError, wrong tensor, or silent success."""
    rng = np.random.default_rng(8)
    for name, payload, decode in _sample_payloads():
        versions = [4, 3, 2] if name != "pq" else [4, 3, 2, 1]
        if name == "pq-delta":
            versions = [4, 3]
        for version in versions:
            frame = wire._legacy_frame(payload, version)
            cuts = set(rng.integers(0, len(frame), size=24).tolist())
            cuts |= {0, 1, wire.HEADER_BYTES - 1, wire.HEADER_BYTES,
                     len(frame) - 1}
            for cut in sorted(cuts):
                with pytest.raises(wire.WireError):
                    decode(frame[:cut])


def test_duplication_and_trailing_garbage_rejected():
    for name, payload, decode in _sample_payloads():
        with pytest.raises(wire.WireError):
            decode(payload + payload)               # duplicated frame
        with pytest.raises(wire.WireError):
            decode(payload + b"\x00\x01\x02\x03")   # trailing garbage


def test_legacy_bitflips_never_escape_the_error_hierarchy():
    """Pre-CRC frames cannot detect every flip, but a flip must only ever
    produce a typed WireError or a controlled decode — no IndexError or
    crash from deep inside the unpackers."""
    rng = np.random.default_rng(9)
    for name, payload, decode in _sample_payloads():
        if name == "pq-delta":
            continue                                # v3 covered below
        frame = wire._legacy_frame(payload, 2)
        for bitpos in rng.choice(len(frame) * 8, size=96, replace=False):
            buf = bytearray(frame)
            buf[bitpos // 8] ^= 1 << (bitpos % 8)
            try:
                decode(bytes(buf))
            except wire.WireError:
                pass


def test_pq_delta_epoch_lineage():
    """The epoch word round-trips, and a mismatched receiver epoch raises
    WireResyncError (the signal to request a full-codebook resync)."""
    cfg, qb, ref = _delta_pair()
    payload, _ = wire.encode_pq_delta(qb, ref, 8, epoch=5)
    assert wire.pq_delta_epoch(payload) == 5
    wire.decode_pq_delta(payload, ref, expected_epoch=5)    # in sync
    wire.decode_pq_delta(payload, ref)                      # check skipped
    with pytest.raises(wire.WireResyncError, match="epoch 5"):
        wire.decode_pq_delta(payload, ref, expected_epoch=6)
    with pytest.raises(wire.WireResyncError, match="resync"):
        wire.decode_pq_delta(payload, ref[:, :1], expected_epoch=5)


def test_delta_codebook_link_resync():
    """The stateful link ships a full codebook when unsynced, deltas once
    synced, and recovers from a forced resync with epochs in lockstep."""
    from repro.core.quantizer import quantize_stateful
    cfg = PQConfig(num_subvectors=8, num_clusters=16, kmeans_iters=3)
    sender = wire.DeltaCodebookLink()
    receiver = wire.DeltaCodebookLink()
    st = None
    for i in range(3):
        z = jax.random.normal(jax.random.PRNGKey(40 + i), (24, 64))
        qb, st = quantize_stateful(z, cfg, st)
        payload = sender.encode(qb)
        expect = "pq" if i == 0 else "pq-delta"
        assert wire.payload_kind(payload) == expect
        wb = receiver.decode(payload)
        np.testing.assert_array_equal(wb.codes, np.asarray(qb.codes))
        np.testing.assert_array_equal(wb.codebooks, sender.ref)
        assert receiver.epoch == sender.epoch == 1
    # receiver loses lineage (say, a restored checkpoint): stale-epoch
    # deltas are rejected, the resync handshake restores the loop
    receiver.epoch = 0
    z = jax.random.normal(jax.random.PRNGKey(50), (24, 64))
    qb, st = quantize_stateful(z, cfg, st)
    with pytest.raises(wire.WireResyncError):
        receiver.decode(sender.encode(qb))
    receiver.request_resync()
    sender.request_resync()
    payload = sender.encode(qb)
    assert wire.payload_kind(payload) == "pq"
    wb = receiver.decode(payload)
    np.testing.assert_array_equal(wb.codebooks, sender.ref)
    assert receiver.epoch == sender.epoch == 1    # lockstep re-established
    # and the loop carries deltas again
    z = jax.random.normal(jax.random.PRNGKey(51), (24, 64))
    qb, st = quantize_stateful(z, cfg, st)
    payload = sender.encode(qb)
    assert wire.payload_kind(payload) == "pq-delta"
    np.testing.assert_array_equal(receiver.decode(payload).codebooks,
                                  sender.ref)
