"""Cohort execution engine tests (PR 5 tentpole).

Covers: the executor registry; the stacked backend's bitwise preservation
of the pre-engine trainer (together with test_scheduler.py's
run-vs-manual-loop pin, which IS the pre-PR contract); stacked-vs-mesh
parity for every scheduler policy on a forced multi-device CPU mesh
(loss/params allclose, identical participant sets and traced bytes, shard
placement recorded); per-client EF/cut-state round-trips across executors
(the satellite per-client warm-start keying); the stateful downlink hook;
and the trace-driven autoscaler's deterministic rules on canned traces.

The mesh-only tests need >= 4 devices and skip otherwise — the CI mesh leg
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(one subprocess smoke below exercises the mesh path even in a
single-device tier-1 run).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core.quantizer import PQConfig, quantize, quantize_stateful
from repro.data.synthetic import make_federated_image_data
from repro.federated import (AsyncBuffer, AutoscalePlan, Deadline,
                             DropSlowestK, FederatedTrainer, FullSync,
                             TraceAutoscaler, lognormal_fleet, make_executor,
                             make_policy)
from repro.federated.executor import (MeshExecutor, StackedExecutor,
                                      available_executors)
from repro.federated.trace import RoundRecord, Trace
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd

MESH_DEVICES = 4
needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < MESH_DEVICES,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4)")

PQ = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)


def _trainer(executor="stacked", policy=None, fleet=None, per_client=True,
             **kw):
    data = make_federated_image_data(num_clients=8, seed=0)
    model = FemnistCNN(pq=PQ, lam=1e-4,
                       client_batch=8 if per_client else 0)
    return FederatedTrainer(model, sgd(0.03), data, cohort=4, client_batch=8,
                            fleet=fleet, policy=policy, executor=executor,
                            **kw)


def _straggler_fleet():
    return lognormal_fleet(8, median_uplink_bps=2e6, seed=3)


# ---------------------------------------------------------------------------
# registry + spec parsing
# ---------------------------------------------------------------------------

def test_registry_lists_both_backends():
    assert set(available_executors()) >= {"stacked", "mesh"}


def test_make_executor_specs():
    assert isinstance(make_executor("stacked"), StackedExecutor)
    assert isinstance(make_executor(None), StackedExecutor)
    ex = make_executor("mesh(shards=2)")
    assert isinstance(ex, MeshExecutor) and ex.shards == 2
    inst = StackedExecutor()
    assert make_executor(inst) is inst
    with pytest.raises(ValueError, match="unknown executor"):
        make_executor("hamster_wheel")
    with pytest.raises(ValueError, match="key=value"):
        make_executor("mesh(4)")


def test_executor_instance_cannot_be_shared_across_trainers():
    """Sharing one executor instance would cross-wire the first trainer to
    the second's model/optimizer — bind() must refuse re-targeting."""
    ex = StackedExecutor()
    _trainer(executor=ex)
    with pytest.raises(ValueError, match="already bound"):
        _trainer(executor=ex)


# ---------------------------------------------------------------------------
# stacked backend: bitwise preservation of the pre-engine trainer
# ---------------------------------------------------------------------------

def test_stacked_spec_variants_bitwise_identical():
    """Default construction, the explicit spec and an instance all select
    the same bitwise trajectory under a straggler policy (the stacked path
    is the pre-engine behavior: test_scheduler.py pins run() == the manual
    pre-PR round loop on the ideal profile)."""
    key = jax.random.PRNGKey(0)
    results = []
    for executor in ("stacked", StackedExecutor()):
        tr = _trainer(executor=executor, policy=DropSlowestK(1),
                      fleet=_straggler_fleet(), per_client=False)
        state, hist = tr.run(3, key)
        results.append((state, [h["loss"] for h in hist]))
    (s1, l1), (s2, l2) = results
    assert l1 == l2
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_routes_through_executor_bitwise():
    """round() through the stacked executor == the historical fused-batch
    step on the identically sampled cohort."""
    key = jax.random.PRNGKey(0)
    tr = _trainer(per_client=False)
    state = tr.init_state(key)
    s1, m1 = tr.round(state, jax.random.fold_in(key, 1))

    tr2 = _trainer(per_client=False)
    state2 = tr2.init_state(key)
    batch = tr2.cohort_batch(jax.random.fold_in(key, 1))
    s2, m2 = tr2.executor._step(state2, batch)
    assert float(m1["loss"]) == float(m2["loss"])
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stacked-vs-mesh parity (forced multi-device mesh)
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("policy_fn,heterogeneous", [
    (FullSync, False),
    (lambda: DropSlowestK(1), True),
    (lambda: Deadline(6.0), True),
    (lambda: AsyncBuffer(2), True),
])
def test_mesh_reproduces_stacked(policy_fn, heterogeneous):
    """executor='mesh' reproduces executor='stacked' round metrics
    (loss allclose), final params (allclose), participant sets, traced
    bytes — for every scheduler policy — and records shard placement."""
    fleet = _straggler_fleet() if heterogeneous else None
    key = jax.random.PRNGKey(0)
    ts = _trainer("stacked", policy_fn(), fleet)
    ss, hs = ts.run(2, key)
    tm = _trainer("mesh", policy_fn(), fleet)
    sm, hm = tm.run(2, key)

    np.testing.assert_allclose([h["loss"] for h in hs],
                               [h["loss"] for h in hm], rtol=5e-4)
    np.testing.assert_allclose([h["ce"] for h in hs],
                               [h["ce"] for h in hm], rtol=5e-4)
    for a, b in zip(jax.tree.leaves(ss.params), jax.tree.leaves(sm.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)
    rs, rm = ts.last_trace, tm.last_trace
    assert [r.participants for r in rs] == [r.participants for r in rm]
    assert [r.uplink_bytes for r in rs] == [r.uplink_bytes for r in rm]
    assert [r.downlink_bytes for r in rs] == [r.downlink_bytes for r in rm]
    assert rm.meta["executor"] == "mesh"
    assert rm.meta["executor_shards"] == len(jax.devices())
    # placement recorded: multi-participant rounds span more than one shard
    assert all(max(r.shards, default=0) > 0
               for r in rm if len(r.participants) > 1)
    assert all(set(r.shards) == {0} for r in rs)  # stacked: single device


@needs_mesh
def test_mesh_placement_contiguous_blocks():
    tr = _trainer("mesh")
    ex = tr.executor
    from repro.federated.scheduler import Arrival
    parts = [Arrival(client=c, version=0, t_arrival=0.0) for c in range(5)]
    placed = ex.place(parts)
    # 5 participants on 4 shards -> 8 padded slots, 2 per shard
    assert [a.shard for a in placed] == [0, 0, 1, 1, 2]
    assert [a.client for a in placed] == [0, 1, 2, 3, 4]


@needs_mesh
def test_mesh_cut_state_round_trip_matches_stacked():
    """Per-client EF memories and warm-start codebooks absorbed from mesh
    rounds match the stacked path's (the client-keyed lineage survives the
    device round-trip)."""
    key = jax.random.PRNGKey(0)
    ts = _trainer("stacked", warm_start=True, error_feedback=True)
    ts.run(3, key)
    tm = _trainer("mesh", warm_start=True, error_feedback=True)
    tm.run(3, key)
    assert set(ts._client_q) == set(tm._client_q)
    assert set(ts._ef_memory) == set(tm._ef_memory)
    for cid in ts._client_q:
        a, b = ts._client_q[cid], tm._client_q[cid]
        assert int(a.rounds) == int(b.rounds)
        np.testing.assert_allclose(np.asarray(a.codebooks),
                                   np.asarray(b.codebooks),
                                   rtol=5e-3, atol=5e-4)
    for cid in ts._ef_memory:
        np.testing.assert_allclose(np.asarray(ts._ef_memory[cid]),
                                   np.asarray(tm._ef_memory[cid]),
                                   rtol=5e-3, atol=5e-4)


def test_mesh_smoke_via_subprocess():
    """Even a single-device tier-1 run exercises the mesh backend once:
    a child process with forced host devices runs one stacked-vs-mesh
    round and asserts loss parity."""
    code = r"""
import jax, numpy as np
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated import FederatedTrainer
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd
assert len(jax.devices()) == 4, jax.devices()
data = make_federated_image_data(num_clients=4, seed=0)
pq = PQConfig(num_subvectors=1152, num_clusters=2, kmeans_iters=1)
losses = []
for ex in ("stacked", "mesh"):
    model = FemnistCNN(pq=pq, lam=1e-4, client_batch=4)
    tr = FederatedTrainer(model, sgd(0.03), data, cohort=2, client_batch=4,
                          executor=ex)
    _, hist = tr.run(1, jax.random.PRNGKey(0))
    losses.append(hist[0]["loss"])
np.testing.assert_allclose(losses[0], losses[1], rtol=5e-4)
print("MESH_SMOKE_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MESH_SMOKE_OK" in proc.stdout


# ---------------------------------------------------------------------------
# per-client warm-start keying on the stacked path (satellite)
# ---------------------------------------------------------------------------

def test_stacked_warm_start_keyed_by_client_survives_reshuffle():
    """DropSlowestK reshuffles cohort composition every round; per-client
    keying must keep each client's codebook lineage instead of resetting
    it with the cohort, and first-time clients are seeded warm."""
    tr = _trainer(policy=DropSlowestK(1), fleet=_straggler_fleet(),
                  warm_start=True)
    tr.run(5, jax.random.PRNGKey(0))
    assert len(tr._client_q) > 0
    rounds = [int(q.rounds) for q in tr._client_q.values()]
    # lineage continued across reshuffled cohorts for repeat participants
    assert max(rounds) >= 2
    # cohort-global slot unused: the per-client path owns the state
    assert tr._global_q is None
    # round 2 onward ran warm: a fresh gather succeeds for ANY cohort
    # (first-timers seeded from the latest absorbed codebook)
    st = tr._gather_client_q([0, 1, 2, 3, 4, 5])
    assert st is not None and st.codebooks.shape[0] == 6


def test_cohort_global_model_keeps_global_slot():
    """client_batch=0 models quantize the whole cohort with one codebook:
    the lineage stays in the cohort-global slot (historical behavior)."""
    tr = _trainer(warm_start=True, per_client=False)
    tr.run(3, jax.random.PRNGKey(0))
    assert tr._global_q is not None
    assert int(tr._global_q.rounds) == 3
    assert tr._client_q == {}


# ---------------------------------------------------------------------------
# stateful downlink hook (satellite: pq-delta covers both directions)
# ---------------------------------------------------------------------------

def test_downlink_stateful_cold_matches_stateless():
    comp = C.PQCompressor(cfg=PQConfig(num_subvectors=8, num_clusters=4,
                                       kmeans_iters=2))
    z = jax.random.normal(jax.random.PRNGKey(0), (12, 64))
    gt = jax.random.normal(jax.random.PRNGKey(1), (12, 64))
    _, vjp0 = jax.vjp(lambda x: C.compress_downlink(x, comp), z)
    _, vjp1 = jax.vjp(lambda x: C.compress_downlink_stateful(x, None, comp),
                      z)
    np.testing.assert_array_equal(np.asarray(vjp0(gt)[0]),
                                  np.asarray(vjp1(gt)[0]))


def test_downlink_stateful_warm_uses_state_codebooks():
    """warm_iters=0 pins Lloyd to the incoming state's codebooks exactly:
    the backward reconstruction must equal quantization under those
    codebooks, and the state gets a zero cotangent."""
    cfg = PQConfig(num_subvectors=8, num_clusters=4, kmeans_iters=3,
                   warm_iters=0)
    comp = C.PQCompressor(cfg=cfg)
    z = jax.random.normal(jax.random.PRNGKey(0), (12, 64))
    gt = jax.random.normal(jax.random.PRNGKey(1), (12, 64))
    gref = jax.random.normal(jax.random.PRNGKey(2), (12, 64))
    _, state = quantize_stateful(gref, cfg)

    out, vjp = jax.vjp(
        lambda x, s: C.compress_downlink_stateful(x, s, comp), z, state)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z))  # identity
    gz, gstate = vjp(gt)
    expect = quantize(gt, cfg, state=state).dequantized
    np.testing.assert_allclose(np.asarray(gz), np.asarray(expect),
                               rtol=1e-6, atol=1e-7)
    assert float(jnp.abs(gstate.codebooks).max()) == 0.0  # carry, no grad


def test_trainer_measures_downlink_delta_bytes():
    tr = _trainer(downlink_compressor="pq", codebook_delta_bits=8)
    state = tr.init_state(jax.random.PRNGKey(0))
    _, down = tr.measure_round_bytes(state, jax.random.PRNGKey(1))
    meta = tr.last_codebook_meta
    assert down == meta["downlink_bytes_delta_codebook"]
    assert meta["downlink_codebook_bytes_delta"] < \
        meta["downlink_codebook_bytes_full"]
    assert meta["downlink_codebook_bytes_reduction"] > 1.0
    # both directions measured: the uplink keys keep their historical names
    assert meta["uplink_bytes_delta_codebook"] > 0


# ---------------------------------------------------------------------------
# trace-driven autoscaler: deterministic rules on canned traces
# ---------------------------------------------------------------------------

def _rec(i, dur, participants=4, dropped=0, loss=None, up=1000, down=1000):
    return RoundRecord(
        round=i, t_start=float(i * 10), t_end=float(i * 10) + dur,
        participants=tuple(range(participants)),
        dropped=tuple(range(100, 100 + dropped)),
        uplink_bytes=up, downlink_bytes=down,
        metrics={} if loss is None else {"loss": loss})


def _trace(durs, losses=None, dropped=0, up=1000, down=1000):
    losses = losses or [None] * len(durs)
    t = Trace()
    for i, (d, l) in enumerate(zip(durs, losses)):
        t.append(_rec(i, d, dropped=dropped, loss=l, up=up, down=down))
    return t


def test_autoscaler_is_deterministic():
    trace = _trace([1, 1, 1, 1, 1, 1, 1, 5],
                   losses=[5, 4.8, 4.6, 4.4, 4.2, 4.0, 3.8, 3.6])
    ctl = TraceAutoscaler(window=8)
    plan = AutoscalePlan(cohort=4)
    outs = [ctl.recommend(trace, plan) for _ in range(3)]
    assert outs[0] == outs[1] == outs[2]


def test_autoscaler_straggler_tail_bounds_rounds():
    trace = _trace([1, 1, 1, 1, 1, 1, 1, 5])
    ctl = TraceAutoscaler(window=8, tail_hi=1.8, deadline_slack=1.5)
    plan = ctl.recommend(trace, AutoscalePlan(cohort=4))
    assert plan.policy.startswith("deadline:")
    assert float(plan.policy.split(":")[1]) == pytest.approx(1.5)  # 1.5*p50
    assert plan.cohort == 4
    assert "straggler tail" in plan.reason


def test_autoscaler_backs_off_aggressive_policy():
    trace = _trace([2] * 8, dropped=3)        # 3 of 7 lost: 43% > 30%
    ctl = TraceAutoscaler(window=8)
    plan = ctl.recommend(trace, AutoscalePlan(cohort=4, policy="deadline:2"))
    assert plan.policy == "deadline:4"        # loosened, cohort untouched
    plan2 = ctl.recommend(trace,
                          AutoscalePlan(cohort=4, policy="drop_slowest:2"))
    assert plan2.policy == "drop_slowest:1"


def test_autoscaler_bytes_budget_escalates_codec_then_cohort():
    trace = _trace([1] * 8, up=4000, down=4000)
    ctl = TraceAutoscaler(window=8, bytes_budget_per_round=1000.0)
    p0 = AutoscalePlan(cohort=8)
    p1 = ctl.recommend(trace, p0)
    assert p1.downlink == "scalarq(bits=8)" and p1.cohort == 8
    p2 = ctl.recommend(trace, p1)
    assert p2.downlink == "chain:topk(k=0.1)+scalarq(bits=8)"
    p3 = ctl.recommend(trace, p2)
    assert p3.cohort == 4                     # ladder exhausted: shed clients


def test_autoscaler_grows_when_healthy_shrinks_on_plateau():
    improving = _trace([1] * 8, losses=[5.0 - 0.2 * i for i in range(8)])
    ctl = TraceAutoscaler(window=8)
    grown = ctl.recommend(improving, AutoscalePlan(cohort=4))
    assert grown.cohort == 8

    flat = _trace([1] * 8, losses=[3.0] * 8)
    shrunk = ctl.recommend(flat, AutoscalePlan(cohort=8))
    assert shrunk.cohort == 4

    steady = ctl.recommend(flat, AutoscalePlan(cohort=2))
    assert steady.cohort == 2 and steady.reason == "steady"


def test_autoscaler_empty_trace_is_noop():
    ctl = TraceAutoscaler()
    plan = AutoscalePlan(cohort=4)
    assert ctl.recommend(Trace(), plan) == plan


def test_make_policy_round_trips_specs():
    assert isinstance(make_policy("full_sync"), FullSync)
    assert make_policy("drop_slowest:2").k == 2
    assert make_policy("deadline:6.5").seconds == 6.5
    assert make_policy("async:3").buffer_size == 3
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("psychic")


def test_trace_windowed_observations():
    trace = _trace([1, 1, 1, 1, 1, 1, 1, 5],
                   losses=[5, 4.8, 4.6, 4.4, 4.2, 4.0, 3.8, 3.6],
                   dropped=1)
    assert trace.duration_percentile(50.0) == pytest.approx(1.0)
    assert trace.tail_ratio() > 2.0
    assert trace.drop_rate() == pytest.approx(8 / (8 + 32))
    assert trace.bytes_per_round() == pytest.approx(2000.0)
    assert trace.loss_slope() == pytest.approx(-0.2)
    assert trace.window(3) == trace.records[-3:]
    assert Trace().tail_ratio() == 1.0 and Trace().loss_slope() == 0.0
