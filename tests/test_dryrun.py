"""Integration test for the multi-pod dry-run machinery (deliverable e).

Runs launch/dryrun.py in a subprocess (XLA device-count flags must be set
before jax initializes, so in-process testing is impossible) for one cheap
(arch × shape) on both production meshes, and checks the recorded artifact.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_one_combo(tmp_path, mesh):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "starcoder2_3b", "--shape", "decode_32k",
         "--mesh", mesh, "--out", str(tmp_path), "--force"],
        env=env, capture_output=True, text=True, timeout=540, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.load(open(tmp_path / f"starcoder2_3b__decode_32k__{mesh}.json"))
    assert "error" not in rec
    assert rec["world"] == (512 if mesh == "multi" else 256)
    assert rec["fits_16GiB"]
    assert rec["roofline"]["bound"] in ("compute", "memory", "collective")
    assert rec["cost"]["flops"] > 0
    assert rec["collectives"]  # sharded program must communicate


def test_dryrun_skip_note(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3_8b", "--shape", "long_500k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=240, cwd=REPO)
    assert proc.returncode == 0
    rec = json.load(open(tmp_path / "llama3_8b__long_500k__single.json"))
    assert "skipped" in rec  # full attention @ 500k: skip-with-note
