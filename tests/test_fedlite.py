"""System-level FedLite tests: the paper's algorithmic claims as asserts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedlite import TrainState, comm_report, make_train_step
from repro.core.quantizer import PQConfig
from repro.core.split import split_summary
from repro.data.synthetic import make_federated_image_data
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd, adam


def _cnn_batch(key, n=16):
    data = make_federated_image_data(num_clients=4, seed=0)
    return data.eval_batch(key, n)


def test_splitfed_equals_minibatch_sgd():
    """Paper §3: SplitFed (no quantization) is EXACTLY mini-batch SGD on the
    full model — client and server updates together equal one SGD step."""
    model = FemnistCNN()
    params = model.init(jax.random.PRNGKey(0))
    batch = _cnn_batch(jax.random.PRNGKey(1))
    lr = 0.1

    # SplitFed step via the framework
    opt = sgd(lr)
    step = make_train_step(model, opt, quantize=False, donate=False)
    state = TrainState.create(params, opt)
    state2, _ = step(state, batch)

    # plain mini-batch SGD on the un-split model
    g = jax.grad(lambda p: model.loss(p, batch, quantize=False)[0])(params)
    manual = jax.tree.map(lambda p, gg: p - lr * gg, params, g)

    for a, b in zip(jax.tree.leaves(state2.params), jax.tree.leaves(manual)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_fedlite_grad_reduces_to_splitfed_without_quantization_error():
    """If the quantizer reconstructs exactly (enough clusters for the data),
    FedLite's corrected gradient == SplitFed's gradient."""
    # q=1 (whole-vector K-means): identical inputs -> identical activation
    # rows -> the single centroid reconstructs them exactly
    model_q = FemnistCNN(pq=PQConfig(num_subvectors=1, num_clusters=2,
                                     kmeans_iters=8), lam=0.5)
    params = model_q.init(jax.random.PRNGKey(0))
    img = jnp.ones((8, 28, 28, 1))
    batch = {"image": img, "label": jnp.zeros((8,), jnp.int32)}
    g_q = jax.grad(lambda p: model_q.loss(p, batch)[0])(params)
    g_s = jax.grad(lambda p: model_q.loss(p, batch, quantize=False)[0])(params)
    for a, b in zip(jax.tree.leaves(g_q), jax.tree.leaves(g_s)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_fedlite_trains_with_high_compression():
    """A few steps of FedLite at ~600x compression still reduce the loss."""
    pq = PQConfig(num_subvectors=1152, num_clusters=2, kmeans_iters=4)
    model = FemnistCNN(pq=pq, lam=1e-4)
    opt = sgd(10 ** -1.0)
    step = make_train_step(model, opt, donate=False)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    batch = _cnn_batch(jax.random.PRNGKey(2), 32)
    losses = []
    for i in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert m["pq_compression_ratio"] > 400


def test_comm_report_matches_paper_table1():
    """Table 1 / §5: uplink accounting for FedAvg vs SplitFed vs FedLite."""
    pq = PQConfig(num_subvectors=1152, num_clusters=2, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    params = model.init(jax.random.PRNGKey(0))
    model_d = 9216
    B = 20
    # monkey-typed: FemnistCNN has no .cfg.d_model; build the report manually
    # at the paper's fixed accounting width phi=64 (tree_bits would otherwise
    # derive phi=32 from the fp32 params)
    from repro.core.split import tree_bits
    client_bits = tree_bits(params["client"], phi_bits=64)
    act_bits = 64 * model_d * B
    msg_bits = pq.message_bits(B, model_d)
    # paper's 490x on the activation payload
    assert act_bits / msg_bits == pytest.approx(490.2, abs=0.5)
    # SplitFed uplink = |w_c| + B·d (paper §3)
    splitfed = client_bits + act_bits
    fedlite = client_bits + msg_bits
    assert splitfed / fedlite > 9  # paper: "about 10x smaller overall uplink"


def test_tree_bits_derives_width_from_dtype():
    """Default accounting counts each leaf at its actual dtype width; an
    explicit phi_bits reproduces the paper's fixed-width model."""
    from repro.core.split import tree_bits
    tree = {"a": jnp.zeros((4,), jnp.float32), "b": jnp.zeros((2,), jnp.bfloat16)}
    assert tree_bits(tree) == 4 * 32 + 2 * 16
    assert tree_bits(tree, phi_bits=64) == 6 * 64


def test_comm_report_default_phi_tracks_dtype():
    """With phi unset, the report accounts fp32 activations at 32 bits."""
    from repro.configs.base import get_arch
    from repro.launch.specs import make_model
    cfg = get_arch("llama3_8b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep32 = comm_report(model, params, tokens_per_client=64)
    rep64 = comm_report(model, params, tokens_per_client=64, phi_bits=64)
    assert rep32["phi_bits"] == 32.0 and rep64["phi_bits"] == 64.0
    assert rep64["splitfed_activation_bits"] == \
        2 * rep32["splitfed_activation_bits"]


def test_split_summary_client_fraction():
    """§5: FEMNIST client-side model ~1.6% of total parameters."""
    model = FemnistCNN()
    params = model.init(jax.random.PRNGKey(0))
    s = split_summary(params)
    assert 0.01 < s["client_fraction"] < 0.025


def test_transformer_comm_report():
    from repro.configs.base import get_arch
    from repro.launch.specs import make_model
    cfg = get_arch("llama3_8b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rep = comm_report(model, params, tokens_per_client=128)
    assert rep["activation_compression_ratio"] > 10
    assert rep["fedlite_uplink_bits"] < rep["splitfed_uplink_bits"]
    assert rep["splitfed_uplink_bits"] < rep["fedavg_uplink_bits"]


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation (microbatches=m) == single-batch step (fp32)."""
    from repro.configs.base import get_arch
    from repro.data.synthetic import make_lm_batch
    from repro.launch.specs import make_model
    cfg = get_arch("llama3_8b", smoke=True)
    model = make_model(cfg, with_pq=False)
    opt = sgd(0.1)
    batch = make_lm_batch(jax.random.PRNGKey(1), 8, 32, cfg.vocab_size)
    s1 = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    s2 = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    st1, _ = make_train_step(model, opt, quantize=False, donate=False)(s1, batch)
    st2, _ = make_train_step(model, opt, quantize=False, microbatches=4,
                             donate=False)(s2, batch)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(a, b, atol=2e-6)


def test_lambda_schedule_no_recompile_and_effective():
    """Scheduled λ: step 0 behaves like λ=0, later steps apply correction."""
    import jax.numpy as jnp
    from repro.core.quantizer import PQConfig
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=3)
    model = FemnistCNN(pq=pq, lam=0.123, client_batch=0)
    opt = sgd(0.0)  # lr 0: isolate gradient computation
    sched = lambda step: jnp.where(step < 1, 0.0, 0.5)
    step = make_train_step(model, opt, lam_schedule=sched, donate=False)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    batch = _cnn_batch(jax.random.PRNGKey(2), 8)

    # compare client grads at step 0 (λ=0) vs an explicit λ=0 model
    g_sched = jax.grad(lambda p: model.loss(p, batch, lam_override=sched(
        jnp.zeros((), jnp.int32)))[0])(state.params)
    model0 = FemnistCNN(pq=pq, lam=0.0, client_batch=0)
    g_zero = jax.grad(lambda p: model0.loss(p, batch)[0])(state.params)
    for a, b in zip(jax.tree.leaves(g_sched), jax.tree.leaves(g_zero)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)
