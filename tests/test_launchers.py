"""CLI smoke tests for the production launchers (subprocess, reduced cfgs)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, timeout=420):
    return subprocess.run([sys.executable, "-m"] + args, env=ENV, cwd=REPO,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli_dense(tmp_path):
    p = _run(["repro.launch.train", "--arch", "llama3_8b", "--smoke",
              "--steps", "3", "--batch", "2", "--seq", "32",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert p.returncode == 0, p.stderr[-1500:]
    assert "uplink compression" in p.stdout
    assert any(f.startswith("ckpt_") for f in os.listdir(tmp_path))


def test_train_cli_audio():
    p = _run(["repro.launch.train", "--arch", "musicgen_large", "--smoke",
              "--steps", "2", "--batch", "2", "--seq", "16"])
    assert p.returncode == 0, p.stderr[-1500:]
    assert "done" in p.stdout


def test_serve_cli_ssm():
    p = _run(["repro.launch.serve", "--arch", "mamba2_1p3b", "--smoke",
              "--batch", "2", "--prompt-len", "16", "--gen", "3"])
    assert p.returncode == 0, p.stderr[-1500:]
    assert "decode:" in p.stdout
