"""Fused Lloyd-update kernel + cross-round warm-start tests (PR 4 tentpole).

Covers: jnp-vs-pallas(interpret) parity of the update statistics and of full
Lloyd runs — including empty clusters and padded tails — the fp32
fixed-point semantics (exact-cover and empty clusters), warm-start reaching
<= cold-start distortion at ``warm_iters`` on stationary inputs, and the
state lifecycle of ``quantize_stateful`` / `PQCompressor.compress_stateful`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kmeans as km
from repro.core.compressors import (CutState, PQCompressor,
                                    compress_with_correction_carry)
from repro.core.quantizer import (PQConfig, QuantizerState, quantize,
                                  quantize_stateful)
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# kernel parity (ops.lloyd_update vs ref.lloyd_update_ref)
# ---------------------------------------------------------------------------

# n=513 exercises the padded tail (not a block multiple); L=5 exercises the
# lane-padded codebook (not a multiple of 8)
@pytest.mark.parametrize("n,d,l", [(64, 8, 4), (513, 8, 5), (128, 16, 16)])
def test_update_kernel_matches_ref(n, d, l):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, d))
    c = jax.random.normal(jax.random.PRNGKey(n + 1), (l, d))
    w = jnp.ones((n,), jnp.float32)
    ds, ct = ops.lloyd_update(x, c, w, block_n=64, interpret=True)
    ds_r, ct_r = ref.lloyd_update_ref(x, w, c, jnp.ones(l))
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ct), np.asarray(ct_r), rtol=1e-6)


def test_update_kernel_zero_weight_rows_contribute_nothing():
    """Padding rows (weight 0) must contribute exactly 0 — the wrapper's
    internal padding relies on it."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    c = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    w = jnp.concatenate([jnp.ones(16), jnp.zeros(16)])
    ds, ct = ops.lloyd_update(x, c, w, interpret=True)
    ds_r, ct_r = ref.lloyd_update_ref(x[:16], jnp.ones(16), c, jnp.ones(4))
    np.testing.assert_allclose(np.asarray(ds), np.asarray(ds_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ct), np.asarray(ct_r))


def test_update_kernel_empty_cluster_exact_zero():
    """A centroid no point selects reports count 0 and an exactly-zero
    deviation sum (the caller keeps the previous centroid bitwise)."""
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (64, 4))
    c = jnp.concatenate([jnp.zeros((1, 4)), jnp.full((1, 4), 1e6)])
    ds, ct = ops.lloyd_update(x, c, interpret=True)
    assert float(ct[1]) == 0.0
    assert float(jnp.abs(ds[1]).max()) == 0.0


def test_update_kernel_exact_cover_exact_zero():
    """Members equal to their centroid contribute an exactly-zero update
    (deviation accumulation) — the FedLite == SplitFed invariant."""
    row = jax.random.normal(jax.random.PRNGKey(3), (1, 8))
    x = jnp.tile(row, (16, 1))
    c = jnp.concatenate([row, row + 100.0])
    ds, ct = ops.lloyd_update(x, c, interpret=True)
    assert float(jnp.abs(ds).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(ct), [16.0, 0.0])


# ---------------------------------------------------------------------------
# backend parity of full Lloyd runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [100, 512])   # 100: padded tail inside chunks
def test_lloyd_backend_parity(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, 8))
    c_j = km.lloyd(x, 8, 5, chunk=64, backend="jnp")
    c_p = km.lloyd(x, 8, 5, chunk=64, backend="pallas")
    np.testing.assert_allclose(np.asarray(c_j), np.asarray(c_p),
                               rtol=1e-5, atol=1e-5)


def test_lloyd_backend_parity_with_empty_clusters():
    """Seeding 8 centroids on 2 tight blobs leaves empty clusters; both
    backends must keep them at their previous position bitwise."""
    blobs = jnp.concatenate([jnp.zeros((32, 4)), jnp.full((32, 4), 10.0)])
    init = jnp.stack([jnp.full((4,), v) for v in
                      [0.0, 10.0, 100.0, 200.0]])
    c_j = km.lloyd(blobs, 4, 4, backend="jnp", init_centroids=init)
    c_p = km.lloyd(blobs, 4, 4, backend="pallas", init_centroids=init)
    # the two far-away centroids never get members: kept exactly
    np.testing.assert_array_equal(np.asarray(c_j[2:]), np.asarray(init[2:]))
    np.testing.assert_array_equal(np.asarray(c_p[2:]), np.asarray(init[2:]))
    np.testing.assert_allclose(np.asarray(c_j), np.asarray(c_p),
                               rtol=1e-6, atol=1e-6)


def test_registered_backend_without_update_falls_back_to_scan():
    """A backend registered with no ``update`` slot must keep working via
    the assign-based scan (back-compat for external backends)."""
    b = km.get_backend("jnp")
    km.register_backend(km.Backend("noupdate", b.assign, b.encode))
    try:
        x = jax.random.normal(jax.random.PRNGKey(4), (200, 8))
        c1 = km.lloyd(x, 4, 3, backend="noupdate")
        c2 = km.lloyd(x, 4, 3, backend="jnp")
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    finally:
        km._REGISTRY.pop("noupdate", None)


# ---------------------------------------------------------------------------
# warm-start
# ---------------------------------------------------------------------------

def test_warm_start_zero_iters_returns_init():
    x = jax.random.normal(jax.random.PRNGKey(5), (128, 8))
    init = km.lloyd(x, 4, 3)
    out = km.lloyd(x, 4, 0, init_centroids=init)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(init))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_warm_start_beats_cold_at_warm_iters_stationary(backend):
    """On stationary inputs, warm-starting from a converged codebook at
    ``warm_iters`` must reach <= the distortion of a cold start given the
    same (reduced) iteration budget — the whole point of the reuse."""
    cfg = PQConfig(num_subvectors=4, num_clusters=8, kmeans_iters=6,
                   backend=backend)
    z1 = jax.random.normal(jax.random.PRNGKey(6), (64, 32))
    z2 = jax.random.normal(jax.random.PRNGKey(7), (64, 32))  # same dist
    _, state = quantize_stateful(z1, cfg)
    warm = quantize(z2, cfg, state=state)
    cold_short = quantize(z2, PQConfig(num_subvectors=4, num_clusters=8,
                                       kmeans_iters=cfg.effective_warm_iters,
                                       backend=backend))
    cold_full = quantize(z2, cfg)
    assert float(warm.distortion) <= float(cold_short.distortion) * 1.05
    # and warm at half budget stays in the cold-full ballpark
    assert float(warm.distortion) <= float(cold_full.distortion) * 1.25


def test_quantize_stateful_lifecycle():
    cfg = PQConfig(num_subvectors=2, num_clusters=4, kmeans_iters=4,
                   warm_iters=1)
    assert cfg.effective_warm_iters == 1
    z = jax.random.normal(jax.random.PRNGKey(8), (32, 16))
    qb, s1 = quantize_stateful(z, cfg)
    assert isinstance(s1, QuantizerState)
    assert s1.codebooks.dtype == jnp.float32
    assert s1.codebooks.shape == (1, 4, 8)
    assert int(s1.rounds) == 1
    _, s2 = quantize_stateful(z, cfg, s1)
    assert int(s2.rounds) == 2


def test_default_warm_iters_is_half():
    assert PQConfig(num_subvectors=1, num_clusters=2,
                    kmeans_iters=8).effective_warm_iters == 4
    with pytest.raises(ValueError):
        PQConfig(num_subvectors=1, num_clusters=2, warm_iters=-1)


def test_single_kmeans_run_with_carry_hook(monkeypatch):
    """The warm-start hook preserves the one-kmeans-per-forward+backward
    invariant: Lloyd and the fused encode each trace exactly once."""
    calls = {"lloyd": 0}
    real = km.batched_lloyd

    def counting(*a, **kw):
        calls["lloyd"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(km, "batched_lloyd", counting)
    cfg = PQConfig(num_subvectors=2, num_clusters=4, kmeans_iters=3,
                   backend="jnp")
    comp = PQCompressor(cfg)
    z = jax.random.normal(jax.random.PRNGKey(9), (16, 16))
    state = CutState(quantizer=None, ef_memory=None)

    def loss(a):
        recon, dist, new_state = compress_with_correction_carry(
            a, 0.5, state, comp)
        return jnp.sum(recon ** 2)

    val, grad = jax.value_and_grad(loss)(z)
    assert np.isfinite(float(val)) and np.isfinite(np.asarray(grad)).all()
    assert calls["lloyd"] == 1


def test_carry_hook_correction_and_state():
    """eq.-5 backward + state round counting through the carry hook."""
    cfg = PQConfig(num_subvectors=2, num_clusters=4, kmeans_iters=3)
    comp = PQCompressor(cfg)
    z = jax.random.normal(jax.random.PRNGKey(10), (16, 16))
    lam = 0.7
    (recon, dist, st1), vjp = jax.vjp(
        lambda a: compress_with_correction_carry(a, lam, CutState(), comp), z)
    g = jax.random.normal(jax.random.PRNGKey(11), (16, 16))
    (gz,) = vjp((g, jnp.zeros(()), jax.tree.map(jnp.zeros_like, st1)))
    np.testing.assert_allclose(np.asarray(gz),
                               np.asarray(g + lam * (z - recon)),
                               rtol=1e-5, atol=1e-6)
    assert int(st1.quantizer.rounds) == 1
    # warm second round
    _, _, st2 = compress_with_correction_carry(z, lam, st1, comp)
    assert int(st2.quantizer.rounds) == 2


def test_carry_hook_error_feedback_telescopes():
    """mem' = (z + mem) − recon; over T rounds the transmitted sum equals
    the input sum + mem_0 − mem_T (exact telescoping, any codec)."""
    from repro.core.compressors import TopKCompressor
    comp = TopKCompressor(k=0.25)
    zs = [jax.random.normal(jax.random.PRNGKey(20 + t), (8, 16))
          for t in range(4)]
    state = CutState(quantizer=None, ef_memory=jnp.zeros((8, 16)))
    sent = jnp.zeros((8, 16))
    for z in zs:
        recon, _, state = compress_with_correction_carry(z, 0.0, state, comp)
        sent = sent + recon
    total = sum(zs)
    np.testing.assert_allclose(np.asarray(sent + state.ef_memory),
                               np.asarray(total), rtol=1e-4, atol=1e-5)
