"""HLO collective-parsing + roofline-term unit tests (no devices needed)."""

import pytest

from repro.launch import analysis

HLO = """
ENTRY %main {
  %ag = bf16[16,512,128]{2,1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[8,64]{1,0} reduce-scatter(%z), replica_groups=[32,8]<=[256], dimensions={0}
  %a2a = bf16[4,256]{1,0} all-to-all(%w), replica_groups=[16,16]<=[256]
  %cp = f32[2,2]{1,0} collective-permute(%v), source_target_pairs={{0,1},{1,0}}
  %ags = bf16[16,512,128]{2,1,0} all-gather-start(%x2), replica_groups=[16,16]<=[256]
  %agd = bf16[16,512,128]{2,1,0} all-gather-done(%ags)
}
"""


def test_collective_stats_counts_and_bytes():
    s = analysis.collective_stats(HLO, world=256)
    assert s["all-gather"]["count"] == 2          # -start counted, -done not
    ag_payload = 16 * 512 * 128 * 2
    assert s["all-gather"]["payload_bytes"] == 2 * ag_payload
    # ring discount (g-1)/g with g=16
    assert s["all-gather"]["wire_bytes"] == pytest.approx(
        2 * ag_payload * 15 / 16)
    # all-reduce: explicit group of 4, factor 2(g-1)/g
    ar_payload = 1024 * 4
    assert s["all-reduce"]["wire_bytes"] == pytest.approx(
        ar_payload * 2 * 3 / 4)
    # reduce-scatter group size 8 from iota [32,8]
    rs_payload = 8 * 64 * 2
    assert s["reduce-scatter"]["wire_bytes"] == pytest.approx(
        rs_payload * 7 / 8)
    assert s["collective-permute"]["wire_bytes"] == 2 * 2 * 4
    assert analysis.total_wire_bytes(s) > 0


def test_group_size_fallback_to_world():
    s = analysis.collective_stats(
        "%ar = f32[64]{0} all-reduce(%x), to_apply=%add\n", world=8)
    assert s["all-reduce"]["wire_bytes"] == pytest.approx(64 * 4 * 2 * 7 / 8)


def test_payload_handles_tuples():
    s = analysis.collective_stats(
        "%ar = (f32[8]{0}, bf16[4]{0}) all-reduce(%a, %b), "
        "replica_groups={{0,1}}\n", world=2)
    assert s["all-reduce"]["payload_bytes"] == 8 * 4 + 4 * 2


def test_roofline_terms_dominance():
    r = analysis.roofline_terms(197e12, 819e9, 0.0, peak_flops=197e12,
                                hbm_bw=819e9, ici_bw=50e9)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["bound"] in ("compute", "memory")
    r2 = analysis.roofline_terms(1e12, 1e9, 400e9, peak_flops=197e12,
                                 hbm_bw=819e9, ici_bw=50e9)
    assert r2["bound"] == "collective"
    assert r2["step_time_lower_bound_s"] == pytest.approx(
        max(r2["compute_s"], r2["memory_s"], r2["collective_s"]))
