"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates its REDUCED family variant (<=2 periods,
d_model <= 512, <= 4 experts) and runs, on CPU:
  * one forward/loss evaluation — asserts shape + no NaN,
  * one full FedLite train step (quantizer + gradient correction + optimizer),
  * prefill + one decode step — asserts logits match the train-mode forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.core.fedlite import TrainState, make_train_step
from repro.core.quantizer import PQConfig
from repro.models.transformer import TransformerLM
from repro.optim import get_optimizer

B, S = 2, 32


def _pq(cfg):
    return PQConfig(num_subvectors=cfg.d_model // 8, num_clusters=4,
                    kmeans_iters=3)


def _batch(cfg, key, seq=S):
    ks = jax.random.split(key, 4)
    if cfg.family == "vlm":
        s_vis = seq // 4
        s_txt = seq - s_vis
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (3, B, seq))
        return {
            "tokens": jax.random.randint(ks[0], (B, s_txt), 0, cfg.vocab_size),
            "vision_embeds": jax.random.normal(ks[1], (B, s_vis,
                                                       cfg.vision_embed_dim)),
            "positions": pos,
            "labels": jnp.concatenate(
                [jnp.full((B, s_vis), -1, jnp.int32),
                 jax.random.randint(ks[2], (B, s_txt), 0, cfg.vocab_size)], 1),
        }
    if cfg.num_codebooks > 1:
        t = jax.random.randint(ks[0], (B, cfg.num_codebooks, seq), 0,
                               cfg.vocab_size)
        return {"tokens": t, "labels": t}
    t = jax.random.randint(ks[0], (B, seq), 0, cfg.vocab_size)
    return {"tokens": t, "labels": jnp.roll(t, -1, axis=1)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    assert cfg.d_model <= 512 and cfg.num_layers <= 4 and cfg.num_experts <= 4
    model = TransformerLM(cfg, pq=_pq(cfg), lam=1e-4)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0

    opt = get_optimizer("adam", 1e-3)
    step = make_train_step(model, opt, donate=False)
    state = TrainState.create(params, opt)
    state2, m = step(state, batch)
    assert int(state2.step) == 1
    for leaf in jax.tree.leaves(state2.params):
        assert not bool(jnp.isnan(leaf).any()), f"{arch}: NaN after step"
    # loss decreases over a few steps on a fixed batch
    st_ = state2
    for _ in range(3):
        st_, m2 = step(st_, batch)
    assert float(m2["loss"]) < float(m["loss"]), f"{arch}: no progress"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    import dataclasses
    cfg = get_arch(arch, smoke=True)
    if cfg.family == "vlm":
        pytest.skip("vlm decode exercised via shapes in dry-run (needs "
                    "m-rope position plumbing for mixed prompts)")
    if cfg.num_experts:
        # ample capacity: token drops differ between prefill(S-1) and full(S)
        # passes and would break the exact-match property being tested
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = TransformerLM(cfg)  # no quantizer: exact match check
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"]

    # full forward last-token logits
    acts, _, _ = model.client_forward(params["client"], batch, mode="train")
    x, _, _ = model.server_forward(params["server"], acts, batch, mode="train")
    lg_full = model.logits(params, x)[:, -1]

    caches = model.init_caches(B, S + 4)
    pre = {k: (v[..., :S - 1] if k == "tokens" and cfg.num_codebooks > 1
               else (v[:, :S - 1] if k == "tokens" else v))
           for k, v in batch.items() if k == "tokens"}
    _, caches = model.prefill(params, pre, caches)
    last = toks[..., S - 1:] if cfg.num_codebooks > 1 else toks[:, S - 1:]
    lg_dec, _ = model.decode_step(params, caches, last, S - 1)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0], np.float32),
                               np.asarray(lg_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment_table():
    """The exact published numbers from the assignment block."""
    expect = {
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "mamba2_1p3b": (48, 2048, 0, 0, 0, 50280),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "jamba_v0p1_52b": (32, 4096, 32, 8, 14336, 65536),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "llama4_maverick_400b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151936),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_arch(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
        assert cfg.source, arch


def test_moe_and_ssm_structure():
    mix = get_arch("mixtral_8x22b")
    assert mix.num_experts == 8 and mix.experts_per_token == 2
    jam = get_arch("jamba_v0p1_52b")
    assert jam.layer_pattern.count("attn") == 1 and len(jam.layer_pattern) == 8
    assert jam.num_experts == 16 and jam.moe_period == 2
    mam = get_arch("mamba2_1p3b")
    assert mam.ssm_state == 128 and mam.layer_pattern == ("ssm",)
    l4 = get_arch("llama4_maverick_400b")
    assert l4.num_experts == 128 and l4.experts_per_token == 1
