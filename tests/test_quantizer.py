"""Unit + property tests for the grouped product quantizer (paper §4.1).

``hypothesis`` is a dev-only dependency (requirements-dev.txt); without it
the property tests skip instead of aborting collection.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.quantizer import (PQConfig, quantization_error, quantize,
                                  vanilla_kmeans_config, vanilla_pq_config)


def test_compression_ratio_490x():
    """Paper §5 worked example: q=1152, L=2, d=9216, B=20, φ=64 -> 490×."""
    cfg = PQConfig(num_subvectors=1152, num_clusters=2)
    assert cfg.compression_ratio(20, 9216) == pytest.approx(490.2, abs=0.5)


def test_message_bits_formula():
    """codebook φ·d·R·L/q + codes B·q·log2(L) (paper §4.1)."""
    cfg = PQConfig(num_subvectors=288, num_clusters=8, num_groups=4,
                   phi_bits=64)
    d, n = 9216, 20
    assert cfg.codebook_bits(d) == 64 * d * 4 * 8 // 288
    assert cfg.codes_bits(n) == n * 288 * 3
    assert cfg.message_bits(n, d) == cfg.codebook_bits(d) + cfg.codes_bits(n)


def test_special_cases_match_paper_baselines():
    km = vanilla_kmeans_config(8)
    assert km.q == 1 and km.r == 1
    pq = vanilla_pq_config(16, 8)
    assert pq.q == pq.r == 16


def test_quantize_shapes_and_reconstruction():
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (64, 48))
    cfg = PQConfig(num_subvectors=6, num_clusters=8, num_groups=2,
                   kmeans_iters=10)
    qb = quantize(z, cfg)
    assert qb.dequantized.shape == z.shape
    assert qb.codes.shape == (2, 3 * 64)
    assert qb.codebooks.shape == (2, 8, 8)
    assert not jnp.isnan(qb.dequantized).any()


def test_exact_reconstruction_when_clusters_cover_data():
    """L >= distinct subvectors => zero quantization error."""
    protos = jnp.asarray(np.random.RandomState(0).randn(4, 32).astype(np.float32))
    idx = np.random.RandomState(1).randint(0, 4, size=128)
    z = protos[idx]
    cfg = PQConfig(num_subvectors=4, num_clusters=16, kmeans_iters=20)
    err = quantization_error(z, cfg)
    assert float(err) < 1e-3


def test_grouping_tradeoff_matches_fig3():
    """Fig. 3's orderings: (a) more subvectors (q up, R=q) lowers error at
    equal L; (b) grouping (R=1) hugely increases compression at equal q."""
    key = jax.random.PRNGKey(42)
    z = jax.random.normal(key, (128, 64)) + \
        jnp.repeat(jax.random.normal(jax.random.PRNGKey(1), (8, 64)) * 2.0,
                   16, axis=0)
    L = 4
    err_kmeans = quantization_error(z, vanilla_kmeans_config(L, kmeans_iters=15))
    err_pq = quantization_error(z, vanilla_pq_config(8, L, kmeans_iters=15))
    assert float(err_pq) < float(err_kmeans)  # subvector division helps

    cfg_grouped = PQConfig(num_subvectors=8, num_clusters=L, num_groups=1)
    cfg_vanilla = vanilla_pq_config(8, L)
    n, d = z.shape
    assert cfg_grouped.compression_ratio(n, d) > \
        4 * cfg_vanilla.compression_ratio(n, d)  # grouping: codebook /8


def test_validation_errors():
    with pytest.raises(ValueError):
        PQConfig(num_subvectors=6, num_clusters=4, num_groups=4)  # q % R != 0
    cfg = PQConfig(num_subvectors=5, num_clusters=4)
    with pytest.raises(ValueError):
        cfg.subvector_dim(16)  # d % q != 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(4, 64),
        dsub=st.integers(1, 8),
        q=st.sampled_from([1, 2, 4, 8]),
        r_div=st.sampled_from([1, 2, 4]),
        L=st.integers(2, 8),
    )
    def test_property_quantizer_invariants(n, dsub, q, r_div, L):
        """Invariants: shape preservation, codes in range, error >= 0 and
        never worse than quantizing to the single mean (L=1 upper bound)."""
        r = max(q // r_div, 1)
        d = q * dsub
        z = jax.random.normal(jax.random.PRNGKey(n * 7 + q), (n, d))
        cfg = PQConfig(num_subvectors=q, num_clusters=L, num_groups=r,
                       kmeans_iters=4)
        qb = quantize(z, cfg)
        assert qb.dequantized.shape == (n, d)
        assert int(qb.codes.max()) < L and int(qb.codes.min()) >= 0
        err_L = float(jnp.mean(jnp.sum((z - qb.dequantized) ** 2, -1)))
        cfg1 = PQConfig(num_subvectors=q, num_clusters=1, num_groups=r,
                        kmeans_iters=4)
        err_1 = float(jnp.mean(jnp.sum((z - quantize(z, cfg1).dequantized) ** 2,
                                       -1)))
        assert err_L <= err_1 + 1e-4
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_property_quantizer_invariants():
        pass


def test_residual_is_fused_and_consistent():
    """QuantizedBatch.residual == z − z̃ (fp32) and backs the distortion."""
    z = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    cfg = PQConfig(num_subvectors=4, num_clusters=4, kmeans_iters=6)
    qb = quantize(z, cfg)
    np.testing.assert_allclose(qb.residual, z - qb.dequantized,
                               rtol=1e-6, atol=1e-6)
    per_vec = float(jnp.sum(qb.residual ** 2) / z.shape[0])
    assert float(qb.distortion) == pytest.approx(per_vec, rel=1e-6)


def test_backend_validation():
    with pytest.raises(ValueError):
        PQConfig(num_subvectors=4, num_clusters=4, backend="mosaic")


def test_quantize_under_jit_and_vmap():
    cfg = PQConfig(num_subvectors=4, num_clusters=4, kmeans_iters=3)
    z = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32))
    out = jax.jit(jax.vmap(lambda zi: quantize(zi, cfg).dequantized))(z)
    assert out.shape == z.shape
