"""§Roofline report: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits the per-(arch × shape × mesh) three-term roofline table.

Terms (seconds, per device, TPU v5e constants from launch/mesh.py):
    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = wire_bytes / (links · link_bw)
plus MODEL_FLOPS/HLO_FLOPs (useful-compute fraction) and the dominant term.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        r["_file"] = os.path.basename(f)
        recs.append(r)
    return recs


def run(fast: bool = True):
    rows = []
    ok = bad = skipped = 0
    for r in load_records():
        if "skipped" in r:
            skipped += 1
            continue
        if "error" in r:
            bad += 1
            rows.append({"name": f"{r['arch']}/{r['shape']}/{r['mesh']}",
                         "us_per_call": 0.0, "status": "ERROR"})
            continue
        ok += 1
        roof = r["roofline"]
        rows.append({
            "name": f"{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": roof["step_time_lower_bound_s"] * 1e6,
            "bound": roof["bound"],
            "compute_ms": round(roof["compute_s"] * 1e3, 3),
            "memory_ms": round(roof["memory_s"] * 1e3, 3),
            "collective_ms": round(roof["collective_s"] * 1e3, 3),
            "GiB_per_device": round(r["device_bytes"] / 2 ** 30, 2),
            "fits": r["fits_16GiB"],
            "useful_flops_frac": round(r["useful_flops_fraction"], 3),
        })
    rows.append({"name": "summary", "us_per_call": 0.0, "ok": ok,
                 "errors": bad, "skipped_noted": skipped})
    return rows


def main(fast: bool = True):
    from benchmarks.common import emit
    emit(run(fast), "roofline")


if __name__ == "__main__":
    main()
