"""Render EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run JSON artifacts. Invoked manually after a sweep:

    PYTHONPATH=src python -m benchmarks.make_tables [--update-experiments]
"""

from __future__ import annotations

import argparse
import re

from benchmarks.roofline import load_records

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    return f"{b / 2 ** 30:.2f}"


def dryrun_table(recs, mesh: str) -> str:
    lines = [
        "| arch | shape | GiB/dev | fits(raw) | TPU-bf16 est | compile s | "
        "collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    by = {}
    for r in recs:
        if r.get("mesh", "") in (mesh, r.get("mesh")) and (
                ("single" in r["_file"]) == (mesh == "single")):
            by[(r["arch"], r["shape"])] = r
    for (arch, shape), r in sorted(by.items(),
                                   key=lambda kv: (kv[0][0],
                                                   SHAPE_ORDER.index(kv[0][1]))):
        if "skipped" in r:
            lines.append(f"| {arch} | {shape} | — | skipped (full attention "
                         f"@500k; DESIGN.md §3) | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {arch} | {shape} | — | ERROR | — | — | — |")
            continue
        est = r.get("tpu_bf16_estimate", {})
        est_s = (f"{est['device_bytes_estimate'] / 2**30:.1f} GiB "
                 f"({'fits' if est.get('fits_16GiB_estimate') else 'over'})"
                 if "device_bytes_estimate" in est else
                 ("n/a (fits raw)" if r["fits_16GiB"] else "—"))
        colls = ", ".join(f"{k.replace('collective-', 'c-')}:{int(v['count'])}"
                          for k, v in sorted(r["collectives"].items()))
        lines.append(
            f"| {arch} | {shape} | {fmt_bytes(r['device_bytes'])} | "
            f"{'yes' if r['fits_16GiB'] else 'no'} | {est_s} | "
            f"{r['compile_s']:.0f} | {colls} |")
    return "\n".join(lines)


def roofline_table(recs, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | bound | "
        "useful-FLOPs frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted((r for r in recs if "roofline" in r and
                     ("single" in r["_file"]) == (mesh == "single")),
                    key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))):
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s'] * 1e3:.2f} | "
            f"{ro['memory_s'] * 1e3:.2f} | {ro['collective_s'] * 1e3:.2f} | "
            f"**{ro['bound']}** | {r['useful_flops_fraction']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-experiments", action="store_true")
    args = ap.parse_args()
    recs = load_records()
    out = []
    out.append("### Dry-run — single pod (16×16 = 256 chips)\n")
    out.append(dryrun_table(recs, "single"))
    out.append("\n### Dry-run — multi-pod (2×16×16 = 512 chips)\n")
    out.append(dryrun_table(recs, "multi"))
    out.append("\n### Roofline — single pod (per-device terms)\n")
    out.append(roofline_table(recs, "single"))
    text = "\n".join(out)
    print(text)
    if args.update_experiments:
        import os
        p = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
        md = open(p).read()
        marker = "<!-- GENERATED-TABLES -->"
        if marker in md:
            md = md.split(marker)[0]
        md = md.rstrip() + f"\n\n{marker}\n\n{text}\n"
        open(p, "w").write(md)
        print(f"\n[updated {p}]")


if __name__ == "__main__":
    main()
