"""Paper Fig. 5c: subvector grouping (R<q) vs vanilla PQ (R=q) end-to-end.

Trains FedLite with the grouped quantizer and with vanilla PQ at matched
(q, L) and reports accuracy + compression for both.

Claim validated: grouping reaches an order of magnitude more compression at
(near-)equal accuracy."""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.quantizer import PQConfig, vanilla_pq_config
from repro.data.synthetic import make_federated_image_data
from repro.federated.runtime import FederatedTrainer
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def run(fast: bool = True):
    rounds = 250 if fast else 600
    data = make_federated_image_data(num_clients=32, seed=0)
    eb = data.eval_batch(jax.random.PRNGKey(999), 512)
    rows = []
    q, L = 288, 4
    for name, pq in [
        ("grouped_R1", PQConfig(num_subvectors=q, num_clusters=L,
                                num_groups=1, kmeans_iters=5)),
        ("vanillaPQ_Rq", vanilla_pq_config(q, L, kmeans_iters=5)),
    ]:
        model = FemnistCNN(pq=pq, lam=1e-5, client_batch=20)
        trainer = FederatedTrainer(model, sgd(10 ** -1.5), data, cohort=10,
                                   client_batch=20)
        state, _ = trainer.run(rounds, jax.random.PRNGKey(0))
        acc = float(model.accuracy(state.params, eb))
        rows.append({"name": f"{name}_q{q}_L{L}", "us_per_call": 0.0,
                     "accuracy": round(acc, 4),
                     "compression_ratio":
                         round(pq.compression_ratio(20, 9216), 1)})
    g, v = rows[0], rows[1]
    rows.append({"name": "fig5c_claim", "us_per_call": 0.0,
                 "compression_gain_from_grouping":
                     round(g["compression_ratio"] / v["compression_ratio"], 1),
                 "accuracy_delta": round(g["accuracy"] - v["accuracy"], 4)})
    return rows


def main(fast: bool = True):
    emit(run(fast), "fig5c_grouping")


if __name__ == "__main__":
    main()
