"""Paper Fig. 6: training curves vs cumulative uplink communication.

Runs FedAvg (H local steps), SplitFed and FedLite on the same synthetic
FEMNIST task and reports loss/accuracy at equal *communication* budgets.

Claim validated: per unit of uplink traffic, FedLite converges far ahead of
both baselines (the paper's Fig. 6 ordering)."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.quantizer import PQConfig
from repro.core.split import tree_bits
from repro.data.synthetic import make_federated_image_data
from repro.federated.runtime import FederatedTrainer, fedavg_round
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def run(fast: bool = True):
    rounds = 250 if fast else 500
    data = make_federated_image_data(num_clients=32, seed=0)
    eb = data.eval_batch(jax.random.PRNGKey(999), 512)
    rows = []
    B, d = 20, 9216
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=5)
    # one accounting width for params, activations AND codebooks: the actual
    # fp32 wire width (tree_bits defaults to per-leaf dtype bits = 32 here)
    PHI = 32

    # --- FedLite & SplitFed --------------------------------------------------
    results = {}
    for name, use_pq in [("fedlite", True), ("splitfed", False)]:
        model = FemnistCNN(pq=pq if use_pq else None, lam=1e-5,
                           client_batch=20)
        trainer = FederatedTrainer(model, sgd(10 ** -1.5), data, cohort=10,
                                   client_batch=20, quantize=use_pq)
        state, hist = trainer.run(rounds, jax.random.PRNGKey(0))
        params0 = model.init(jax.random.PRNGKey(0))
        client_bits = tree_bits(params0["client"])
        per_round = client_bits + (pq.message_bits(B, d, phi_bits=PHI)
                                   if use_pq else PHI * d * B)
        acc = float(model.accuracy(state.params, eb))
        results[name] = (acc, per_round * rounds, hist[-1]["loss"])
        rows.append({"name": name, "us_per_call": 0.0,
                     "rounds": rounds, "accuracy": round(acc, 4),
                     "uplink_bits_per_round_per_client": per_round,
                     "total_uplink_MB": round(per_round * rounds * 10 / 8e6, 1),
                     "final_loss": round(hist[-1]["loss"], 4)})

    # --- FedAvg (fewer rounds: each costs the FULL model uplink) ------------
    model = FemnistCNN()
    params = model.init(jax.random.PRNGKey(0))
    full_bits = tree_bits(params)
    fa_rounds = max(rounds // 4, 10)
    rng = np.random.default_rng(0)
    loss = None
    for t in range(fa_rounds):
        ids = rng.choice(data.num_clients, size=10, replace=False)
        params, loss = fedavg_round(model, params, data, ids,
                                    jax.random.fold_in(jax.random.PRNGKey(3), t),
                                    local_steps=4, batch=20, lr=10 ** -1.5)
    acc = float(model.accuracy(params, eb))
    rows.append({"name": "fedavg", "us_per_call": 0.0,
                 "rounds": fa_rounds, "accuracy": round(acc, 4),
                 "uplink_bits_per_round_per_client": full_bits,
                 "total_uplink_MB": round(full_bits * fa_rounds * 10 / 8e6, 1),
                 "final_loss": round(float(loss), 4)})

    # claim: accuracy per MB — fedlite wins by a wide margin
    def acc_per_mb(r):
        return r["accuracy"] / max(r["total_uplink_MB"], 1e-9)
    by = {r["name"]: r for r in rows}
    rows.append({"name": "fig6_claim", "us_per_call": 0.0,
                 "fedlite_acc_per_MB": round(acc_per_mb(by["fedlite"]), 4),
                 "splitfed_acc_per_MB": round(acc_per_mb(by["splitfed"]), 4),
                 "fedavg_acc_per_MB": round(acc_per_mb(by["fedavg"]), 4)})
    return rows


def main(fast: bool = True):
    emit(run(fast), "fig6_convergence")


if __name__ == "__main__":
    main()
