"""Paper Fig. 4: accuracy vs compression-ratio trade-off (FEMNIST task).

Trains the paper's CNN under FedLite for a grid of (q, L), with the paper's
hyperparameters (SGD lr 10^-1.5, B=20 per client, cohort 10, R=1, λ>0), and
reports final eval accuracy + compression ratio per point, plus the SplitFed
(uncompressed) reference.

Claims validated: (i) ≥10x compression with negligible accuracy loss;
(ii) at the 490x point (q=1152, L=2) accuracy stays within a few percent of
SplitFed when λ>0.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated.runtime import FederatedTrainer
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def _train_and_eval(pq, lam, rounds, data, seed=0):
    model = FemnistCNN(pq=pq, lam=lam, client_batch=20)
    trainer = FederatedTrainer(model, sgd(10 ** -1.5), data, cohort=10,
                               client_batch=20, quantize=pq is not None,
                               seed=seed)
    t0 = time.time()
    state, hist = trainer.run(rounds, jax.random.PRNGKey(seed))
    eb = data.eval_batch(jax.random.PRNGKey(999), 512)
    acc = float(model.accuracy(state.params, eb))
    return acc, (time.time() - t0) * 1e6 / rounds, hist[-1]["loss"]


def run(fast: bool = True):
    rounds = 250 if fast else 600
    data = make_federated_image_data(num_clients=32, seed=0)
    rows = []

    acc_ref, us, _ = _train_and_eval(None, 0.0, rounds, data)
    rows.append({"name": "splitfed_reference", "us_per_call": us,
                 "accuracy": round(acc_ref, 4), "compression_ratio": 1.0})

    # λ=1e-5 across the grid (constant-λ sweep picked it; see EXPERIMENTS
    # §Perf — 1e-4 causes activation collapse at L<=4 on this task)
    grid = [(288, 32), (288, 4), (1152, 2)] if fast else \
        [(288, 32), (288, 8), (288, 4), (288, 2), (1152, 4), (1152, 2)]
    for q, L in grid:
        pq = PQConfig(num_subvectors=q, num_clusters=L, kmeans_iters=5)
        acc, us, loss = _train_and_eval(pq, 1e-5, rounds, data)
        rows.append({
            "name": f"fedlite_q{q}_L{L}",
            "us_per_call": us,
            "accuracy": round(acc, 4),
            "compression_ratio": round(pq.compression_ratio(20, 9216), 1),
            "final_loss": round(loss, 4),
            "acc_drop_vs_splitfed": round(acc_ref - acc, 4),
        })
    return rows


def main(fast: bool = True):
    emit(run(fast), "fig4_accuracy_tradeoff")


if __name__ == "__main__":
    main()
