"""Paper Fig. 3: quantization error vs compression ratio.

Compares the paper's grouped quantizer against vanilla K-means (q=1) and
vanilla PQ (R=q) on REAL cut-layer activations: a FEMNIST-architecture CNN is
trained briefly on the synthetic federated data, then a batch of B=20
activations (d=9216, the paper's exact sizes) is quantized under each scheme.

Claim validated: the grouped quantizer (R=1, varying q/L) dominates the
error-vs-ratio frontier of both baselines (green/red-line ordering of Fig 3).

Each row carries a ``backend`` column (jnp | pallas): the same scheme is also
measured through the fused Pallas encode path so the trade-off sweep doubles
as a backend parity/latency comparison (see core/quantizer.py docstring).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.quantizer import (PQConfig, quantization_error,
                                  vanilla_kmeans_config, vanilla_pq_config)
from repro.data.synthetic import make_federated_image_data
from repro.federated.runtime import FederatedTrainer
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def _activations(train_rounds: int = 40, batch: int = 20) -> jax.Array:
    data = make_federated_image_data(num_clients=16, seed=0)
    model = FemnistCNN()
    trainer = FederatedTrainer(model, sgd(10 ** -1.5), data, cohort=8,
                               client_batch=20, quantize=False)
    state, _ = trainer.run(train_rounds, jax.random.PRNGKey(0))
    eb = data.eval_batch(jax.random.PRNGKey(7), batch)
    return model.client_forward(state.params["client"], eb)  # (B, 9216)


def run(fast: bool = True):
    z = _activations(train_rounds=20 if fast else 100)
    d = z.shape[-1]
    B = z.shape[0]
    iters = 6 if fast else 15
    rows = []

    def record(scheme, cfg, backend="jnp"):
        cfg = dataclasses.replace(cfg, backend=backend)
        err = float(quantization_error(z, cfg))
        us = time_call(
            jax.jit(lambda zz: quantization_error(zz, cfg)), z,
            iters=1 if backend == "pallas" else 2)
        rows.append({
            "name": f"{scheme}_q{cfg.q}_L{cfg.l}_R{cfg.r}_{backend}",
            "us_per_call": us,
            "rel_error": round(err, 4),
            "compression_ratio": round(cfg.compression_ratio(B, d), 1),
            "backend": backend,
        })
        return err

    Ls = [2, 8, 32] if fast else [2, 4, 8, 16, 32, 64]
    for L in Ls:
        record("kmeans", vanilla_kmeans_config(L, kmeans_iters=iters))
        record("vanillaPQ", vanilla_pq_config(1152, L, kmeans_iters=iters))
        record("grouped", PQConfig(num_subvectors=1152, num_clusters=L,
                                   num_groups=1, kmeans_iters=iters))
    # grouped curve needs larger L too: grouping's point is affording many
    # more clusters at the same message size
    for L in ([128, 512] if fast else [128, 256, 512, 1024]):
        record("grouped", PQConfig(num_subvectors=1152, num_clusters=L,
                                   num_groups=1, kmeans_iters=iters))

    # backend dimension: identical scheme through the fused-pallas encode
    # (interpret off-TPU — parity datapoint; real speed comparison on TPU)
    for L in [8] if fast else [8, 32]:
        record("grouped", PQConfig(num_subvectors=1152, num_clusters=L,
                                   num_groups=1, kmeans_iters=iters),
               backend="pallas")

    # frontier dominance (Fig. 3's qualitative claim): for every baseline
    # point there is a grouped point that is at least as good on BOTH axes
    g = [r for r in rows if r["name"].startswith("grouped")]
    base = [r for r in rows if not r["name"].startswith("grouped")]
    dominated = sum(
        1 for b in base
        if any(gr["compression_ratio"] >= b["compression_ratio"] - 1e-6 and
               gr["rel_error"] <= b["rel_error"] + 5e-3 for gr in g))
    claims = {
        "baseline_points_dominated": f"{dominated}/{len(base)}",
        "grouped_max_ratio": max(r["compression_ratio"] for r in g),
        "vanilla_pq_max_ratio": max(r["compression_ratio"] for r in base
                                    if "vanillaPQ" in r["name"]),
        "kmeans_max_ratio": max(r["compression_ratio"] for r in base
                                if "kmeans" in r["name"]),
    }
    rows.append({"name": "fig3_claims", "us_per_call": 0.0, **claims})
    return rows


def main(fast: bool = True):
    emit(run(fast), "fig3_quantizer_tradeoff")


if __name__ == "__main__":
    main()
