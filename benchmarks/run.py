"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # fast (default)
  PYTHONPATH=src python -m benchmarks.run --full      # paper-scale grids
  PYTHONPATH=src python -m benchmarks.run --only fig3_quantizer_tradeoff

The ``kernels`` suite additionally writes ``BENCH_kernels.json`` at the
repo root (per-backend Lloyd-update / scalarq / PQ-encode rows + analytic
HBM-traffic models) so the kernel perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_accuracy_tradeoff, bench_comm,
                        bench_convergence, bench_correction, bench_grouping,
                        bench_kernels, bench_network,
                        bench_quantizer_tradeoff, bench_so_tasks, roofline)
from benchmarks.common import emit

SUITES = {
    "fig3_quantizer_tradeoff": bench_quantizer_tradeoff,
    "fig4_accuracy_tradeoff": bench_accuracy_tradeoff,
    "fig5_correction": bench_correction,
    "fig5c_grouping": bench_grouping,
    "table1_comm": bench_comm,
    "network_tradeoff": bench_network,
    "so_tasks": bench_so_tasks,
    "fig6_convergence": bench_convergence,
    "kernels": bench_kernels,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow)")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    for name, mod in suites.items():
        t0 = time.time()
        try:
            rows = mod.run(fast=not args.full)
            emit(rows, name)
            print(f"{name}/_suite_wall,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/_suite_wall,{(time.time() - t0) * 1e6:.0f},"
                  f"ERROR={type(e).__name__}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
