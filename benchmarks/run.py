"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # fast (default)
  PYTHONPATH=src python -m benchmarks.run --full      # paper-scale grids
  PYTHONPATH=src python -m benchmarks.run --only fig3_quantizer_tradeoff
  PYTHONPATH=src python -m benchmarks.run --preflight # fedlint gate only

``--preflight`` runs the same static-analysis invocation as CI
(``python -m repro.lint src benchmarks examples``) and refuses to
benchmark on any finding — a typo'd mesh axis or a hardcoded
``interpret=True`` should fail before a long benchmark run, not during.

The ``kernels``, ``table1_comm`` and ``network_tradeoff`` suites
additionally snapshot their rows as ``BENCH_kernels.json`` /
``BENCH_comm.json`` / ``BENCH_network.json`` at the repo root
(``benchmarks/common.write_bench_json``) so perf and bytes trajectories
are tracked across PRs; after the suites finish this harness prints one
``bench_json/...`` summary row per snapshot it finds.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import (bench_accuracy_tradeoff, bench_comm,
                        bench_convergence, bench_correction, bench_grouping,
                        bench_kernels, bench_network,
                        bench_quantizer_tradeoff, bench_so_tasks, roofline)
from benchmarks.common import REPO_ROOT, emit

SUITES = {
    "fig3_quantizer_tradeoff": bench_quantizer_tradeoff,
    "fig4_accuracy_tradeoff": bench_accuracy_tradeoff,
    "fig5_correction": bench_correction,
    "fig5c_grouping": bench_grouping,
    "table1_comm": bench_comm,
    "network_tradeoff": bench_network,
    "so_tasks": bench_so_tasks,
    "fig6_convergence": bench_convergence,
    "kernels": bench_kernels,
    "roofline": roofline,
}


LINT_TARGETS = ("src", "benchmarks", "examples")


def preflight() -> int:
    """Run the fedlint gate (same invocation as the CI static-analysis
    job); returns the number of findings after printing them."""
    from repro.lint import run_lint
    findings = run_lint(list(LINT_TARGETS))
    for f in findings:
        print(f.format(), file=sys.stderr)
    if findings:
        print(f"preflight: {len(findings)} fedlint finding(s) in "
              f"{' '.join(LINT_TARGETS)} — fix or suppress before "
              "benchmarking", file=sys.stderr)
    return len(findings)


def aggregate_bench_json() -> None:
    """One CSV summary row per ``BENCH_*.json`` snapshot at the repo root
    (whatever suites — this run's or a previous one's — have written)."""
    for path in sorted(REPO_ROOT.glob("BENCH_*.json")):
        try:
            payload = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_json/{path.name},0.0,ERROR={type(e).__name__}")
            continue
        rows = payload.get("rows", [])
        print(f"bench_json/{path.name},0.0,"
              f"suite={payload.get('suite')};rows={len(rows)};"
              f"backend={payload.get('jax_backend')}")
    # trace artifacts (event logs, perfetto exports) live in the
    # gitignored benchmarks/out/ scratch dir, not at the repo root
    out_dir = REPO_ROOT / "benchmarks" / "out"
    for path in sorted(out_dir.glob("*")) if out_dir.is_dir() else []:
        print(f"bench_artifact/{path.name},0.0,"
              f"bytes={path.stat().st_size}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow)")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    ap.add_argument("--preflight", action="store_true",
                    help="run the fedlint static-analysis gate and exit")
    args = ap.parse_args()

    if args.preflight:
        sys.exit(1 if preflight() else 0)

    print("name,us_per_call,derived")
    failures = 0
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    for name, mod in suites.items():
        t0 = time.time()
        try:
            rows = mod.run(fast=not args.full)
            emit(rows, name)
            print(f"{name}/_suite_wall,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/_suite_wall,{(time.time() - t0) * 1e6:.0f},"
                  f"ERROR={type(e).__name__}")
    aggregate_bench_json()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
