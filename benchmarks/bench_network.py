"""Measured time-to-target-loss and bytes-per-round under heterogeneity.

The end-to-end version of the paper's §5 trade-off: the same FEMNIST
training run is dispatched through the virtual-clock scheduler under
compression level x bandwidth distribution x straggler policy, and each
cell reports *measured* wire bytes (``federated/wire.py``) plus simulated
wall-clock — where ``bench_comm.py`` only counts bits analytically.

Scenario axes (fast mode keeps a 2x3 slice; --full runs the grid):

  * compression — SplitFed (raw fp32 activations) vs FedLite
    (q=1152, L=2: the paper's 490x point).
  * fleet       — ideal (identical infinitely-fast clients), lognormal
    broadband (heavy straggler tail), wired/mobile mixture with dropout.
  * policy      — full sync, drop-slowest-k, per-round deadline,
    FedBuff-style async buffer.
  * downlink    — (``--downlink`` / ``downlink=True``) the server->client
    gradient codec: dense vs ``chain:topk(k=0.1)+scalarq(bits=8)``. The
    compressed cell must show >= 8x measured downlink-bytes reduction
    (asserted — acceptance criterion) and still reach the round-0-derived
    target loss.
  * warm-start  — always-on extra cell: cross-round codebook warm-start
    (half the Lloyd iterations per steady-state round) + pq-delta codebook
    wire encoding on the default fleet; must still reach the target loss
    (asserted — acceptance criterion).
  * executor    — (``--executor mesh``) run the scenario cells through the
    cohort-parallel mesh executor (``federated/executor.py``) instead of
    the stacked single-device path, plus a shard-scaling cell: the
    cohort-execute phase (one synchronous server update over a fixed
    8-client cohort) timed at 1/2/4 shards, one child process per shard
    count with ONE DEDICATED CPU CORE PER SHARD (``taskset``) — the CPU
    emulation of one accelerator per shard. On hosts with >= 4 cores the
    4-shard speedup over 1 shard must be >= 1.5x (asserted — acceptance
    criterion); see ``run_executor_scaling`` for the calibrated
    smaller-host bars.
  * fleet scale — (``--fleet-scale``) scheduler-core scaling cells with a
    stub execute: simulated rounds per second and peak RSS at 10^5 and
    10^6 lognormal clients (10^3 / 10^4-client cohorts) under both
    scheduler backends, the 10^6 cells through a `TwoTierTopology` with
    per-tier measured bytes in the row. The 1M-client / 10k-cohort vector
    cell must finish a round inside the wall-clock budget and both
    backends' traces must match bitwise (asserted — acceptance criteria).
  * autoscale   — (``--autoscale``) one training run on the lognormal
    straggler fleet driven by the trace-driven `TraceAutoscaler`
    (``federated/autoscale.py``) in plan-sized segments, next to the
    static (cohort, policy) cells it chooses between. The autoscaled run
    must reach the target loss with NO MORE uplink bytes than the best
    static cell (asserted — acceptance criterion).

Emitted per row: simulated seconds, simulated time and uplink bytes to
reach the target loss (0.9x the round-0 loss), measured uplink AND
downlink MB/round, stragglers dropped, mean staleness. Every run also
snapshots the rows as ``BENCH_network.json`` at the repo root
(``benchmarks/common.write_bench_json``).

``--emit-trace [PATH]`` additionally records the whole run through the
``repro.obs`` telemetry recorder — scheduler rounds on the virtual-clock
lane, executor/wire/host spans on the wall-clock lane, per-round byte
ledgers, and the contribution flight recorder's rollups + exemplar
lifecycles — writing an append-only JSONL event log (default
``benchmarks/out/BENCH_network_trace.jsonl``; the out/ dir is
gitignored scratch) plus a Perfetto-loadable trace_event twin
(``--perfetto PATH`` to relocate it). Summarize the JSONL with
``python -m repro.obs <path>`` (``--health`` grades it against the SLO
rules; ``--flight <client-or-id>`` reconstructs one lifecycle).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit, out_path, write_bench_json
from repro import obs
from repro.obs import flight as flightlib
from repro.obs import slo
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated import (DEFAULT_CHAOS, AsyncBuffer, AutoscalePlan,
                             Deadline, DropSlowestK, FaultPlan,
                             FederatedTrainer, FullSync, Scheduler,
                             TraceAutoscaler, TwoTierTopology,
                             autoscale_run, lognormal_fleet, make_policy,
                             mobile_fleet, uniform_fleet)
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd

NUM_CLIENTS = 16
COHORT = 4
CLIENT_BATCH = 8

DOWNLINK_CHAIN = "chain:topk(k=0.1)+scalarq(bits=8)"

# marker line the shard-scaling leg children print their result through
_SCALING_MARKER = "BENCH_SCALING_LEG:"


def _fleets():
    return {
        "ideal": uniform_fleet(NUM_CLIENTS),
        "lognormal": lognormal_fleet(
            NUM_CLIENTS, median_uplink_bps=2e6, median_downlink_bps=10e6,
            bandwidth_sigma=1.0, compute_sigma=0.4, seed=0),
        "mobile": mobile_fleet(NUM_CLIENTS, flaky_fraction=0.4, seed=0),
    }


def _policies():
    return {
        "full_sync": FullSync(),
        "drop_slowest_1": DropSlowestK(1),
        "deadline_6s": Deadline(6.0),
        "async_buffer_2": AsyncBuffer(2),
    }


def _compressions():
    return {
        "splitfed": None,
        "fedlite_q1152_L2": PQConfig(num_subvectors=1152, num_clusters=2,
                                     kmeans_iters=2),
    }


# fast mode: the three straggler/bandwidth scenarios the acceptance
# criteria name, each at both compression levels
FAST_SCENARIOS = [
    ("ideal", "full_sync"),
    ("lognormal", "drop_slowest_1"),
    ("mobile", "deadline_6s"),
]


def _run_cell(data, fleet, policy, pq, downlink, rounds, fast,
              warm_start=False, delta_bits=None, executor="stacked",
              cohort=COHORT, fault_plan=None):
    # the mesh executor runs per-client math: give the model the matching
    # per-client quantization granularity so both executors cluster alike
    client_batch = CLIENT_BATCH if executor != "stacked" else 0
    model = FemnistCNN(pq=pq, lam=1e-4, client_batch=client_batch)
    trainer = FederatedTrainer(
        model, sgd(10 ** -1.5), data, cohort=cohort,
        client_batch=CLIENT_BATCH, quantize=pq is not None,
        fleet=fleet, policy=policy, downlink_compressor=downlink,
        warm_start=warm_start, codebook_delta_bits=delta_bits,
        executor=executor, fault_plan=fault_plan)
    t0 = time.perf_counter()
    state, hist = trainer.run(rounds, jax.random.PRNGKey(0))
    wall_us = (time.perf_counter() - t0) * 1e6 / max(rounds, 1)
    trace = trainer.last_trace
    losses = [h["loss"] for h in hist if "loss" in h]
    # fast mode only runs 8 rounds; use a reachable smoke target
    factor = 0.93 if fast else 0.9
    target = factor * losses[0] if losses else float("nan")
    t_target = trace.time_to_target(target)
    b_target = trace.bytes_to_target(target)
    s = trace.summary()
    row = {
        "us_per_call": wall_us,
        "sim_seconds": round(s["simulated_seconds"], 2),
        "sim_seconds_to_target": None if t_target is None
        else round(t_target, 2),
        "uplink_mb_to_target": None if b_target is None
        else round(b_target / 1e6, 4),
        "uplink_mb_per_round": round(s["uplink_bytes_per_round"] / 1e6, 4),
        "downlink_mb_per_round": round(
            s["downlink_bytes_per_round"] / 1e6, 4),
        "stragglers_dropped": s["stragglers_dropped"],
        "mean_staleness": round(s["mean_staleness"], 2),
        "final_loss": round(losses[-1], 4) if losses else None,
        "reached_target": t_target is not None,
    }
    return row, trainer, state


def run(fast: bool = True, downlink: bool = False,
        executor: str = "stacked", autoscale: bool = False,
        fleet_scale: bool = False, chaos: bool = False):
    data = make_federated_image_data(num_clients=NUM_CLIENTS, seed=0)
    fleets, policies, pqs = _fleets(), _policies(), _compressions()
    scenarios = FAST_SCENARIOS if fast else \
        [(f, p) for f in fleets for p in policies]
    rounds = 8 if fast else 40

    rows = []
    # historical (stacked) rows keep their names so cross-PR trajectory
    # comparisons keyed on row name stay valid; mesh cells get a suffix
    suffix = "" if executor == "stacked" else f"_{executor}"
    for fleet_name, policy_name in scenarios:
        for pq_name, pq in pqs.items():
            row, _, _ = _run_cell(data, fleets[fleet_name],
                                  policies[policy_name], pq, None,
                                  rounds, fast, executor=executor)
            rows.append(dict(
                {"name": f"{fleet_name}_{policy_name}_{pq_name}"
                         f"{suffix}"}, **row))

    if executor == "stacked":
        # the warm-start cell has no executor dimension; don't re-train it
        # in the mesh smoke when the stacked smoke already covered it
        rows.extend(run_warm_start_cell(data, fleets, policies, rounds,
                                        fast))
    if downlink:
        rows.extend(run_downlink_sweep(data, fleets, policies, rounds, fast))
    if chaos:
        rows.extend(run_chaos_cell(data, fleets, policies, rounds, fast))
    if executor == "mesh":
        rows.extend(run_executor_scaling())
    if autoscale:
        rows.extend(run_autoscale_cell(data, fleets, rounds, fast,
                                       executor=executor))
    if fleet_scale:
        rows.extend(run_fleet_scale(fast))
    # serialize before emit() strips the row keys
    write_bench_json(
        "network", rows,
        note="virtual-clock scheduler cells: measured wire bytes + "
             "simulated wall-clock per (fleet, policy, compression)")
    return rows


def run_warm_start_cell(data, fleets, policies, rounds, fast):
    """Cross-round codebook warm-start on the default (ideal, full-sync)
    fleet: steady-state rounds run PQConfig.warm_iters Lloyd iterations
    from last round's codebook and ship pq-delta codebooks. The run must
    still reach the round-0-derived target loss (acceptance criterion)."""
    pq = _compressions()["fedlite_q1152_L2"]
    row, trainer, _ = _run_cell(
        data, fleets["ideal"], policies["full_sync"], pq, None, rounds,
        fast, warm_start=True, delta_bits=8)
    assert row["reached_target"], \
        "warm-start run failed to reach the target loss"
    meta = trainer.last_trace.meta
    return [dict({"name": "warmstart_delta8_ideal_full_sync_fedlite"}, **row),
            {"name": "warmstart_claim", "us_per_call": 0.0,
             "reached_target": row["reached_target"],
             "codebook_bytes_reduction": round(
                 meta.get("codebook_bytes_reduction", 0.0), 2),
             "warm_iters": pq.effective_warm_iters,
             "cold_iters": pq.kmeans_iters}]


def run_chaos_cell(data, fleets, policies, rounds, fast):
    """The --chaos dimension: seeded fault injection (federated/faults.py)
    over fault-rate x straggler-policy cells on the lognormal fleet.

    Asserts graceful degradation (acceptance criteria):
      * the baseline-rate full-sync cell still reaches the target loss —
        quarantine + retry keep training on track;
      * downlink byte inflation from crash retries stays bounded
        (<= 1.5x the fault-free cell);
      * the chaos canary holds: contributions were quarantined, and NO
        corrupted payload ever slipped past the wire CRC undetected.
    """
    pq = _compressions()["fedlite_q1152_L2"]
    # chaos cells need headroom past the fault-free round count: voided
    # and quarantined rounds make no progress by design
    rounds = rounds * 2
    clean, _, _ = _run_cell(data, fleets["lognormal"],
                            policies["full_sync"], pq, None, rounds, fast)
    clean_dl = clean["downlink_mb_per_round"]
    plans = {
        "baseline": DEFAULT_CHAOS,
        "storm": FaultPlan(seed=0, crash_rate=0.2, corrupt_rate=0.25,
                           poison_rate=0.1, reorder_rate=0.4,
                           reorder_max_s=2.0, quorum_fraction=0.5),
    }
    rows = []
    totals = {}
    for plan_name, plan in plans.items():
        for policy_name in ("full_sync", "drop_slowest_1"):
            row, trainer, _ = _run_cell(
                data, fleets["lognormal"], policies[policy_name], pq, None,
                rounds, fast, fault_plan=plan)
            ft = trainer.last_trace.fault_totals()
            totals[(plan_name, policy_name)] = (row, ft)
            # the run-health signals the SLO monitors grade, as columns:
            # how much extra downlink the crash retries cost, and what
            # fraction of admitted contributions the server quarantined
            health = slo.trace_signals(trainer.last_trace)
            rows.append(dict(
                {"name": f"chaos_{plan_name}_{policy_name}_fedlite"}, **row,
                crashes=ft.get("crashes", 0),
                retries=ft.get("retries", 0),
                crash_dropped=ft.get("crash_dropped", 0),
                quarantined=ft.get("quarantined", 0),
                rounds_voided=ft.get("round_voided", 0),
                corrupt_undetected=ft.get("corrupt_undetected", 0),
                retry_byte_overhead=round(health["retry_byte_overhead"], 4),
                quarantine_rate=round(health["quarantine_rate"], 4),
                downlink_inflation=round(
                    row["downlink_mb_per_round"] / max(clean_dl, 1e-12), 3)))
    base_row, base_ft = totals[("baseline", "full_sync")]
    assert base_row["reached_target"], \
        "baseline-rate chaos run failed to reach the target loss"
    inflation = base_row["downlink_mb_per_round"] / max(clean_dl, 1e-12)
    assert inflation <= 1.5, \
        f"retry downlink inflation {inflation:.2f}x exceeds the 1.5x bound"
    all_ft = [ft for _, ft in totals.values()]
    assert sum(ft.get("quarantined", 0) for ft in all_ft) > 0, \
        "chaos sweep never exercised the quarantine path"
    assert all(ft.get("corrupt_undetected", 0) == 0 for ft in all_ft), \
        "a corrupted payload slipped past the wire CRC undetected"
    rows.append({"name": "chaos_claim", "us_per_call": 0.0,
                 "reached_target": base_row["reached_target"],
                 "baseline_downlink_inflation": round(inflation, 3),
                 "quarantined_total": sum(ft.get("quarantined", 0)
                                          for ft in all_ft),
                 "corrupt_undetected_total": 0})
    return rows


def run_downlink_sweep(data, fleets, policies, rounds, fast):
    """The --downlink dimension: dense vs chained gradient codec on the
    default (ideal, full-sync) fleet, FedLite uplink. The compressed cell
    must cut measured downlink bytes >= 8x (acceptance criterion)."""
    pq = _compressions()["fedlite_q1152_L2"]
    rows = []
    per_round = {}
    for dl_name, dl in [("dense", None), ("topk0.1_sq8", DOWNLINK_CHAIN)]:
        row, trainer, state = _run_cell(
            data, fleets["ideal"], policies["full_sync"], pq, dl,
            rounds, fast)
        per_round[dl_name] = row["downlink_mb_per_round"]
        rows.append(dict(
            {"name": f"downlink_{dl_name}_ideal_full_sync_fedlite"}, **row))
    reduction = per_round["dense"] / max(per_round["topk0.1_sq8"], 1e-12)
    assert reduction >= 8.0, \
        f"measured downlink reduction {reduction:.2f}x below the 8x bar"
    assert rows[-1]["reached_target"], \
        "compressed-downlink run failed to reach the target loss"
    rows.append({
        "name": "downlink_claim",
        "us_per_call": 0.0,
        "measured_downlink_reduction": round(reduction, 1),
        "compressed_reached_target": rows[-1]["reached_target"],
    })
    return rows


# ---------------------------------------------------------------------------
# executor dimension: cohort-execute wall-clock scaling with shard count
# ---------------------------------------------------------------------------

def _scaling_leg(shards: int):
    """One leg of the shard-scaling cell (runs inside its own child
    process, jax initialized with exactly ``shards`` forced host devices):
    time the cohort-execute phase — one synchronous server update over a
    fixed 8-client cohort through the mesh executor — and print the
    min-of-3 wall-clock through the marker line."""
    cohort, batch = 8, 32
    data = make_federated_image_data(num_clients=cohort, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=8, kmeans_iters=6)
    model = FemnistCNN(pq=pq, lam=1e-4, client_batch=batch)
    trainer = FederatedTrainer(
        model, sgd(10 ** -1.5), data, cohort=cohort, client_batch=batch,
        executor=f"mesh(shards={shards})")
    state = trainer.init_state(jax.random.PRNGKey(0))
    parts = [trainer.client_batch_for(c, jax.random.PRNGKey(1))
             for c in range(cohort)]
    ex = trainer.executor
    jax.block_until_ready(ex.execute(state, parts)[0].params)  # compile
    reps = []
    for _ in range(3):
        t0 = time.perf_counter()
        out, _ = ex.execute(state, parts)
        jax.block_until_ready(out.params)
        reps.append(time.perf_counter() - t0)
    print(_SCALING_MARKER + json.dumps({"shards": shards,
                                        "seconds": min(reps)}))


def run_executor_scaling():
    """Cohort-execute wall-clock scaling with shard count.

    Methodology: one child process per shard count with ONE CPU CORE PER
    SHARD (``taskset -c 0..k-1`` where available) and exactly ``k`` forced
    host devices — the CPU emulation of one accelerator per shard, so the
    1-shard baseline cannot borrow the other shards' cores through
    intra-op threading. The asserted bar anchors at the largest shard
    count the host can physically parallelize:

      * >= 4 cores (the CI runner): 4-shard speedup >= 1.5x — the
        acceptance bar.
      * 2-3 cores: 2-shard speedup >= 1.15x. jax's CPU client overlaps
        multi-device execution only partially (measured ~1.3-1.5x of the
        2x ideal on 2 dedicated cores), so the 2-core bar is calibrated to
        that runtime ceiling, not to the mesh design.
      * 1 core: rows only, nothing to assert.
    """
    # the cores THIS process may run on (affinity/cgroup mask), not the
    # host's total — a container limited to 2 of 16 cores must anchor at 2
    try:
        core_ids = sorted(os.sched_getaffinity(0))
    except AttributeError:   # non-Linux: no affinity API, no taskset either
        core_ids = list(range(os.cpu_count() or 1))
    cores = len(core_ids)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    has_taskset = subprocess.run(["which", "taskset"],
                                 capture_output=True).returncode == 0
    times = {}
    # two interleaved passes, min per shard count: shared-host noise drifts
    # over minutes, and min-statistics across interleaved samples converge
    # on the quiet-machine value instead of whichever leg got unlucky
    for _ in range(2):
        for shards in (1, 2, 4):
            env = dict(os.environ)
            env["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={shards}"
            cmd = [sys.executable, "-m", "benchmarks.bench_network",
                   "--_scaling-leg", str(shards)]
            if has_taskset:
                cmd = ["taskset", "-c", ",".join(
                    str(c) for c in core_ids[:min(shards, cores)])] + cmd
            proc = subprocess.run(cmd, env=env, check=True,
                                  capture_output=True, text=True, cwd=repo)
            for line in proc.stdout.splitlines():
                if line.startswith(_SCALING_MARKER):
                    t = json.loads(line[len(_SCALING_MARKER):])["seconds"]
                    times[shards] = min(times.get(shards, t), t)
    rows = [{"name": f"execute_scaling_shards{s}",
             "us_per_call": round(t * 1e6, 1),
             "ms_per_round": round(t * 1e3, 1),
             "cores_used": min(s, cores),
             "speedup_vs_1shard": round(times[1] / t, 2)}
            for s, t in sorted(times.items())]
    anchor = min(4, cores) if cores >= 2 else 1
    if anchor >= 2:
        anchor = 4 if anchor >= 4 else 2
        bar = 1.5 if anchor == 4 else 1.15
        speedup = times[1] / times[anchor]
        assert speedup >= bar, \
            f"mesh cohort-execute speedup {speedup:.2f}x at {anchor} " \
            f"shards ({anchor} dedicated cores) below the {bar}x bar"
        rows.append({"name": "execute_scaling_claim", "us_per_call": 0.0,
                     "anchor_shards": anchor, "host_cores": cores,
                     "speedup": round(speedup, 2), "bar": bar})
    return rows


# ---------------------------------------------------------------------------
# fleet-scale dimension: the vectorized scheduler core at 10^5-10^6 clients
# ---------------------------------------------------------------------------

# wall-clock budget for one simulated round of the 1M-client / 10k-cohort
# vector cell (measured ~0.02 s on the CI-class host; the bar is generous
# because it must hold on loaded shared runners)
FLEET_SCALE_BUDGET_S = 5.0


def _peak_rss_mb() -> float:
    """Peak resident set of this process in MB (0.0 where unavailable)."""
    try:
        import resource
    except ImportError:        # non-POSIX
        return 0.0
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _fleet_scale_cell(fleet, cohort, backend, rounds, topology=None,
                      seed=7):
    """Time ``rounds`` scheduler rounds with a stub execute.

    The cohort sampler is seeded per round (identical across backends) so
    the heapq/vector pair in a cell runs the exact same cohorts and their
    traces can be compared record-for-record.
    """
    n = len(fleet)

    def sample_cohort(rd):
        return np.random.default_rng((seed, rd)).choice(n, cohort,
                                                        replace=False)

    sched = Scheduler(fleet=fleet, policy=DropSlowestK(max(cohort // 10, 1)),
                      client_step_seconds=1.0, seed=seed, backend=backend,
                      topology=topology)
    t0 = time.perf_counter()
    trace = sched.run(rounds, sample_cohort=sample_cohort,
                      uplink_bytes=81920, downlink_bytes=262144,
                      execute=lambda rd, parts, weights: {},
                      wire_kinds=("pq", "dense"))
    wall = (time.perf_counter() - t0) / rounds
    return wall, trace


def run_fleet_scale(fast: bool = True):
    """The ``--fleet-scale`` dimension: scheduler-core scaling cells.

    Pure scheduler throughput (stub execute — the executor's compute is
    the other benchmarks' business): lognormal fleets at 10^5 and 10^6
    clients, 1%-of-fleet cohorts, both backends where affordable. The
    10^6 cells run through a 32-edge `TwoTierTopology`, so their rows
    carry the per-tier measured bytes. Asserted acceptance criteria: the
    1M/10k vector cell finishes a round inside ``FLEET_SCALE_BUDGET_S``
    with both tier ledger entries present and nonzero, and the heapq and
    vector traces of every cell match record-for-record (bitwise parity
    at fleet scale, not just on the small test fleets).
    """
    rounds = 3 if fast else 8
    rows = []
    traces = {}
    cells = [
        (100_000, 1_000, None),
        (1_000_000, 10_000, TwoTierTopology(num_edges=32, seed=0)),
    ]
    for clients, cohort, topo in cells:
        setup0 = time.perf_counter()
        fleet = lognormal_fleet(clients, dropout_prob=0.01, seed=1)
        if topo is not None:
            topo.ensure(clients)       # k-means once, shared by backends
        setup_s = time.perf_counter() - setup0
        for backend in ("heapq", "vector"):
            wall, trace = _fleet_scale_cell(fleet, cohort, backend, rounds,
                                            topology=topo)
            traces[(clients, backend)] = trace
            tiers = trace.tier_totals()
            row = {
                "name": f"fleet_{clients}c_{cohort}cohort_{backend}",
                "us_per_call": round(wall * 1e6, 1),
                "s_per_round": round(wall, 4),
                "clients": clients,
                "cohort": cohort,
                "rounds": rounds,
                "sim_seconds_per_round": round(
                    trace.simulated_seconds / rounds, 2),
                "peak_rss_mb": round(_peak_rss_mb(), 1),
                "setup_s": round(setup_s, 2),
            }
            if topo is not None:
                row["edge_uplink_bytes"] = tiers.get("edge_uplink", 0)
                row["server_uplink_bytes"] = tiers.get("server_uplink", 0)
            rows.append(row)
        # bitwise parity at fleet scale: same cohorts, same records,
        # and the flight recorder saw the identical contribution set
        assert traces[(clients, "heapq")].records \
            == traces[(clients, "vector")].records, \
            f"backend traces diverge at {clients} clients"
        assert traces[(clients, "heapq")].flights \
            == traces[(clients, "vector")].flights, \
            f"backend flight frames diverge at {clients} clients"

    # flights-overhead A/B on the headline cell: re-run the 1M vector
    # cell (fleet/cohort/topo still bound from the last loop iteration)
    # off/on back-to-back. Both legs are warm — the cells loop above
    # already paid the lazy topology clustering and allocator warmup, so
    # neither leg carries setup cost the other doesn't — and the min of
    # two interleaved passes per leg damps shared-host jitter. Recording
    # must cost <= 15% wall-clock at O(cohort) per round.
    wall_off = wall_on = float("inf")
    for _ in range(2):
        prev = flightlib.set_flights(False)
        try:
            w, _ = _fleet_scale_cell(fleet, cohort, "vector", rounds,
                                     topology=topo)
        finally:
            flightlib.set_flights(prev)
        wall_off = min(wall_off, w)
        w, _ = _fleet_scale_cell(fleet, cohort, "vector", rounds,
                                 topology=topo)
        wall_on = min(wall_on, w)

    # the headline acceptance criteria: 1M clients, 10k cohort, vector
    big = next(r for r in rows
               if r["name"] == "fleet_1000000c_10000cohort_vector")
    assert big["s_per_round"] <= FLEET_SCALE_BUDGET_S, \
        f"1M-client vector round took {big['s_per_round']:.2f}s, over " \
        f"the {FLEET_SCALE_BUDGET_S:g}s budget"
    assert big["edge_uplink_bytes"] > 0 and big["server_uplink_bytes"] > 0, \
        f"two-tier ledger entries missing from the 1M cell: {big}"
    assert big["server_uplink_bytes"] < big["edge_uplink_bytes"], \
        "edge pre-combination should shrink the server tier below the " \
        "edge tier"
    # 5 ms absolute slack so a fast host does not turn scheduler jitter
    # into a failed relative bound
    overhead = wall_on / max(wall_off, 1e-9)
    assert wall_on <= max(1.15 * wall_off, wall_off + 0.005), \
        f"flight recording costs {overhead:.2f}x wall-clock on the " \
        f"1M-client vector cell (budget 1.15x)"
    rows.append({
        "name": "fleet_flights_overhead", "us_per_call": 0.0,
        "s_per_round_flights_on": round(wall_on, 4),
        "s_per_round_flights_off": round(wall_off, 4),
        "overhead_x": round(overhead, 3),
    })
    rows.append({
        "name": "fleet_scale_claim", "us_per_call": 0.0,
        "s_per_round_1m_vector": big["s_per_round"],
        "budget_s": FLEET_SCALE_BUDGET_S,
        "speedup_vs_heapq": round(
            next(r for r in rows
                 if r["name"] == "fleet_1000000c_10000cohort_heapq")
            ["s_per_round"] / max(big["s_per_round"], 1e-9), 1),
        "server_vs_edge_bytes": round(
            big["server_uplink_bytes"] / big["edge_uplink_bytes"], 4),
    })
    return rows


# ---------------------------------------------------------------------------
# autoscale dimension: trace-driven (cohort, policy, codec) control
# ---------------------------------------------------------------------------

def run_autoscale_cell(data, fleets, rounds, fast, executor="stacked"):
    """One training run on the lognormal straggler fleet driven by the
    `TraceAutoscaler`, next to the static (cohort, policy) cells it picks
    between. Asserts (acceptance criterion) that the autoscaled run reaches
    the round-0-derived target loss with no more uplink bytes than the best
    static cell."""
    fleet = fleets["lognormal"]
    pq = _compressions()["fedlite_q1152_L2"]
    interval = 4 if fast else 8
    factor = 0.93 if fast else 0.9
    rows = []

    static_bytes = {}
    for pname in ("full_sync", "drop_slowest_1", "deadline_6s"):
        row, _, _ = _run_cell(data, fleet, _policies()[pname], pq, None,
                              rounds, fast, executor=executor)
        static_bytes[pname] = row["uplink_mb_to_target"]
        rows.append(dict({"name": f"autoscale_static_{pname}"}, **row))

    def make_trainer(plan, seg):
        client_batch = CLIENT_BATCH if executor != "stacked" else 0
        model = FemnistCNN(pq=pq, lam=1e-4, client_batch=client_batch)
        return FederatedTrainer(
            model, sgd(10 ** -1.5), data, cohort=plan.cohort,
            client_batch=CLIENT_BATCH, quantize=True, fleet=fleet,
            policy=make_policy(plan.policy),
            downlink_compressor=plan.downlink, seed=seg, executor=executor)

    # max_cohort clamps at the population: sample_clients would silently
    # cap larger cohorts, and the plan rows must report what actually ran
    controller = TraceAutoscaler(window=interval, tail_hi=1.5,
                                 max_cohort=NUM_CLIENTS)
    out = autoscale_run(make_trainer, AutoscalePlan(cohort=COHORT), rounds,
                        jax.random.PRNGKey(0), controller=controller,
                        interval=interval)
    losses = [h["loss"] for h in out["history"] if "loss" in h]
    target = factor * losses[0]
    total = 0
    auto_bytes = None
    for h in out["history"]:
        total += h.get("uplink_bytes", 0)
        if "loss" in h and h["loss"] <= target:
            auto_bytes = total
            break
    assert auto_bytes is not None, \
        "autoscaled run failed to reach the target loss"
    reached = [b for b in static_bytes.values() if b is not None]
    assert reached, \
        f"no static cell reached the target loss: {static_bytes}"
    best_static = min(reached)
    auto_mb = auto_bytes / 1e6
    assert auto_mb <= best_static + 1e-9, \
        f"autoscaled run used {auto_mb:.4f} MB to target vs best static " \
        f"{best_static:.4f} MB"
    for i, plan in enumerate(out["plans"]):
        rows.append({"name": f"autoscale_plan_{i}", "us_per_call": 0.0,
                     "cohort": plan.cohort, "policy": plan.policy,
                     "downlink": plan.downlink or "dense",
                     "reason": plan.reason.replace(",", ";")})
    rows.append({
        "name": "autoscale_claim", "us_per_call": 0.0,
        "uplink_mb_to_target": round(auto_mb, 4),
        "best_static_mb_to_target": round(best_static, 4),
        "plans_applied": len(out["plans"]),
        "final_loss": round(losses[-1], 4),
        "sim_seconds": round(out["simulated_seconds"], 2),
    })
    return rows


def main(fast: bool = True, downlink: bool = False,
         executor: str = "stacked", autoscale: bool = False,
         fleet_scale: bool = False, chaos: bool = False,
         emit_trace: str = None, perfetto: str = None):
    if executor == "mesh" and len(jax.devices()) < 2 \
            and not os.environ.get("_BENCH_MESH_CHILD"):
        # re-exec with forced host devices so the mesh cells see a real
        # mesh (the trace/obs flags ride along through sys.argv)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " \
            + env.get("XLA_FLAGS", "")
        env["_BENCH_MESH_CHILD"] = "1"
        raise SystemExit(subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_network",
             *sys.argv[1:]], env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        ).returncode)
    if emit_trace:
        obs.configure(run="bench_network", meta={
            "suite": "network_tradeoff", "fast": fast, "downlink": downlink,
            "executor": executor, "autoscale": autoscale,
            "fleet_scale": fleet_scale, "chaos": chaos,
            "jax_backend": jax.default_backend()})
    emit(run(fast, downlink=downlink, executor=executor,
             autoscale=autoscale, fleet_scale=fleet_scale, chaos=chaos),
         "network_tradeoff")
    recorder = obs.shutdown()
    if emit_trace and recorder is not None:
        n = recorder.write_jsonl(emit_trace)
        pf = perfetto or (emit_trace[:-len(".jsonl")] + ".perfetto.json"
                          if emit_trace.endswith(".jsonl")
                          else emit_trace + ".perfetto.json")
        recorder.write_perfetto(pf)
        # stdout is the CSV channel (and the scaling-leg marker); report
        # the trace artifacts on stderr
        print(f"wrote {n} events to {emit_trace}; perfetto trace at {pf}\n"
              f"inspect with: python -m repro.obs {emit_trace}",
              file=sys.stderr)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--downlink", action="store_true",
                    help="sweep the downlink gradient codec too")
    ap.add_argument("--executor", choices=["stacked", "mesh"],
                    default="stacked",
                    help="cohort execution engine for the scenario cells; "
                         "mesh adds the shard-scaling cell")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the trace-driven autoscaler cell")
    ap.add_argument("--fleet-scale", action="store_true",
                    help="run the 10^5/10^6-client scheduler-core scaling "
                         "cells (wall-clock budget + backend parity "
                         "asserted)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection sweep (fault rate x "
                         "policy; graceful-degradation + canary "
                         "assertions)")
    ap.add_argument("--emit-trace", nargs="?",
                    const="__default__", default=None,
                    metavar="PATH",
                    help="record an obs telemetry trace of the run and "
                         "write it as JSONL (default "
                         "benchmarks/out/BENCH_network_trace.jsonl — "
                         "gitignored scratch); a Perfetto-loadable twin "
                         "is written next to it")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="where to write the Perfetto trace_event JSON "
                         "(default: the --emit-trace path with .jsonl "
                         "swapped for .perfetto.json)")
    ap.add_argument("--_scaling-leg", type=int, default=0,
                    dest="scaling_leg", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.emit_trace == "__default__":
        args.emit_trace = str(out_path("BENCH_network_trace.jsonl"))
    if args.scaling_leg:
        _scaling_leg(args.scaling_leg)
    else:
        main(fast=not args.full, downlink=args.downlink,
             executor=args.executor, autoscale=args.autoscale,
             fleet_scale=args.fleet_scale, chaos=args.chaos,
             emit_trace=args.emit_trace, perfetto=args.perfetto)
