"""Measured time-to-target-loss and bytes-per-round under heterogeneity.

The end-to-end version of the paper's §5 trade-off: the same FEMNIST
training run is dispatched through the virtual-clock scheduler under
compression level x bandwidth distribution x straggler policy, and each
cell reports *measured* wire bytes (``federated/wire.py``) plus simulated
wall-clock — where ``bench_comm.py`` only counts bits analytically.

Scenario axes (fast mode keeps a 2x3 slice; --full runs the grid):

  * compression — SplitFed (raw fp32 activations) vs FedLite
    (q=1152, L=2: the paper's 490x point).
  * fleet       — ideal (identical infinitely-fast clients), lognormal
    broadband (heavy straggler tail), wired/mobile mixture with dropout.
  * policy      — full sync, drop-slowest-k, per-round deadline,
    FedBuff-style async buffer.
  * downlink    — (``--downlink`` / ``downlink=True``) the server->client
    gradient codec: dense vs ``chain:topk(k=0.1)+scalarq(bits=8)``. The
    compressed cell must show >= 8x measured downlink-bytes reduction
    (asserted — acceptance criterion) and still reach the round-0-derived
    target loss.
  * warm-start  — always-on extra cell: cross-round codebook warm-start
    (half the Lloyd iterations per steady-state round) + pq-delta codebook
    wire encoding on the default fleet; must still reach the target loss
    (asserted — acceptance criterion).

Emitted per row: simulated seconds, simulated time and uplink bytes to
reach the target loss (0.9x the round-0 loss), measured uplink AND
downlink MB/round, stragglers dropped, mean staleness.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated import (AsyncBuffer, Deadline, DropSlowestK,
                             FederatedTrainer, FullSync, lognormal_fleet,
                             mobile_fleet, uniform_fleet)
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd

NUM_CLIENTS = 16
COHORT = 4
CLIENT_BATCH = 8

DOWNLINK_CHAIN = "chain:topk(k=0.1)+scalarq(bits=8)"


def _fleets():
    return {
        "ideal": uniform_fleet(NUM_CLIENTS),
        "lognormal": lognormal_fleet(
            NUM_CLIENTS, median_uplink_bps=2e6, median_downlink_bps=10e6,
            bandwidth_sigma=1.0, compute_sigma=0.4, seed=0),
        "mobile": mobile_fleet(NUM_CLIENTS, flaky_fraction=0.4, seed=0),
    }


def _policies():
    return {
        "full_sync": FullSync(),
        "drop_slowest_1": DropSlowestK(1),
        "deadline_6s": Deadline(6.0),
        "async_buffer_2": AsyncBuffer(2),
    }


def _compressions():
    return {
        "splitfed": None,
        "fedlite_q1152_L2": PQConfig(num_subvectors=1152, num_clusters=2,
                                     kmeans_iters=2),
    }


# fast mode: the three straggler/bandwidth scenarios the acceptance
# criteria name, each at both compression levels
FAST_SCENARIOS = [
    ("ideal", "full_sync"),
    ("lognormal", "drop_slowest_1"),
    ("mobile", "deadline_6s"),
]


def _run_cell(data, fleet, policy, pq, downlink, rounds, fast,
              warm_start=False, delta_bits=None):
    model = FemnistCNN(pq=pq, lam=1e-4)
    trainer = FederatedTrainer(
        model, sgd(10 ** -1.5), data, cohort=COHORT,
        client_batch=CLIENT_BATCH, quantize=pq is not None,
        fleet=fleet, policy=policy, downlink_compressor=downlink,
        warm_start=warm_start, codebook_delta_bits=delta_bits)
    t0 = time.perf_counter()
    state, hist = trainer.run(rounds, jax.random.PRNGKey(0))
    wall_us = (time.perf_counter() - t0) * 1e6 / max(rounds, 1)
    trace = trainer.last_trace
    losses = [h["loss"] for h in hist if "loss" in h]
    # fast mode only runs 8 rounds; use a reachable smoke target
    factor = 0.93 if fast else 0.9
    target = factor * losses[0] if losses else float("nan")
    t_target = trace.time_to_target(target)
    b_target = trace.bytes_to_target(target)
    s = trace.summary()
    row = {
        "us_per_call": wall_us,
        "sim_seconds": round(s["simulated_seconds"], 2),
        "sim_seconds_to_target": None if t_target is None
        else round(t_target, 2),
        "uplink_mb_to_target": None if b_target is None
        else round(b_target / 1e6, 4),
        "uplink_mb_per_round": round(s["uplink_bytes_per_round"] / 1e6, 4),
        "downlink_mb_per_round": round(
            s["downlink_bytes_per_round"] / 1e6, 4),
        "stragglers_dropped": s["stragglers_dropped"],
        "mean_staleness": round(s["mean_staleness"], 2),
        "final_loss": round(losses[-1], 4) if losses else None,
        "reached_target": t_target is not None,
    }
    return row, trainer, state


def run(fast: bool = True, downlink: bool = False):
    data = make_federated_image_data(num_clients=NUM_CLIENTS, seed=0)
    fleets, policies, pqs = _fleets(), _policies(), _compressions()
    scenarios = FAST_SCENARIOS if fast else \
        [(f, p) for f in fleets for p in policies]
    rounds = 8 if fast else 40

    rows = []
    for fleet_name, policy_name in scenarios:
        for pq_name, pq in pqs.items():
            row, _, _ = _run_cell(data, fleets[fleet_name],
                                  policies[policy_name], pq, None,
                                  rounds, fast)
            rows.append(dict(
                {"name": f"{fleet_name}_{policy_name}_{pq_name}"}, **row))

    rows.extend(run_warm_start_cell(data, fleets, policies, rounds, fast))
    if downlink:
        rows.extend(run_downlink_sweep(data, fleets, policies, rounds, fast))
    return rows


def run_warm_start_cell(data, fleets, policies, rounds, fast):
    """Cross-round codebook warm-start on the default (ideal, full-sync)
    fleet: steady-state rounds run PQConfig.warm_iters Lloyd iterations
    from last round's codebook and ship pq-delta codebooks. The run must
    still reach the round-0-derived target loss (acceptance criterion)."""
    pq = _compressions()["fedlite_q1152_L2"]
    row, trainer, _ = _run_cell(
        data, fleets["ideal"], policies["full_sync"], pq, None, rounds,
        fast, warm_start=True, delta_bits=8)
    assert row["reached_target"], \
        "warm-start run failed to reach the target loss"
    meta = trainer.last_trace.meta
    return [dict({"name": "warmstart_delta8_ideal_full_sync_fedlite"}, **row),
            {"name": "warmstart_claim", "us_per_call": 0.0,
             "reached_target": row["reached_target"],
             "codebook_bytes_reduction": round(
                 meta.get("codebook_bytes_reduction", 0.0), 2),
             "warm_iters": pq.effective_warm_iters,
             "cold_iters": pq.kmeans_iters}]


def run_downlink_sweep(data, fleets, policies, rounds, fast):
    """The --downlink dimension: dense vs chained gradient codec on the
    default (ideal, full-sync) fleet, FedLite uplink. The compressed cell
    must cut measured downlink bytes >= 8x (acceptance criterion)."""
    pq = _compressions()["fedlite_q1152_L2"]
    rows = []
    per_round = {}
    for dl_name, dl in [("dense", None), ("topk0.1_sq8", DOWNLINK_CHAIN)]:
        row, trainer, state = _run_cell(
            data, fleets["ideal"], policies["full_sync"], pq, dl,
            rounds, fast)
        per_round[dl_name] = row["downlink_mb_per_round"]
        rows.append(dict(
            {"name": f"downlink_{dl_name}_ideal_full_sync_fedlite"}, **row))
    reduction = per_round["dense"] / max(per_round["topk0.1_sq8"], 1e-12)
    assert reduction >= 8.0, \
        f"measured downlink reduction {reduction:.2f}x below the 8x bar"
    assert rows[-1]["reached_target"], \
        "compressed-downlink run failed to reach the target loss"
    rows.append({
        "name": "downlink_claim",
        "us_per_call": 0.0,
        "measured_downlink_reduction": round(reduction, 1),
        "compressed_reached_target": rows[-1]["reached_target"],
    })
    return rows


def main(fast: bool = True, downlink: bool = False):
    emit(run(fast, downlink=downlink), "network_tradeoff")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--downlink", action="store_true",
                    help="sweep the downlink gradient codec too")
    args = ap.parse_args()
    main(fast=not args.full, downlink=args.downlink)
