"""Paper Fig. 5a/b: the gradient-correction ablation — λ=0 (naive STE) vs
λ>0 at an aggressive compression point.

Claim validated: λ>0 improves accuracy (paper: 3-72% improvements at q=288;
divergence possible at λ=0 in the high-compression regime), while very large
λ collapses the model (activations pulled toward a constant)."""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated.runtime import FederatedTrainer
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def run(fast: bool = True):
    rounds = 250 if fast else 600
    data = make_federated_image_data(num_clients=32, seed=0)
    rows = []
    grid_q = [288] if fast else [288, 1152]
    lams = [0.0, 1e-5, 1e-4] if fast else [0.0, 1e-5, 5e-5, 1e-4, 1e-2]
    for q in grid_q:
        pq = PQConfig(num_subvectors=q, num_clusters=4, kmeans_iters=5)
        accs = {}
        for lam in lams:
            model = FemnistCNN(pq=pq, lam=lam, client_batch=20)
            trainer = FederatedTrainer(model, sgd(10 ** -1.5), data,
                                       cohort=10, client_batch=20)
            state, hist = trainer.run(rounds, jax.random.PRNGKey(1))
            eb = data.eval_batch(jax.random.PRNGKey(999), 512)
            acc = float(model.accuracy(state.params, eb))
            accs[lam] = acc
            rows.append({
                "name": f"q{q}_L4_lambda{lam:g}",
                "us_per_call": 0.0,
                "accuracy": round(acc, 4),
                "final_distortion": round(hist[-1].get("pq_distortion", 0), 3),
            })
        best_pos = max(a for l, a in accs.items() if l > 0)
        rows.append({
            "name": f"q{q}_claim_correction_helps",
            "us_per_call": 0.0,
            "acc_lambda0": round(accs[0.0], 4),
            "best_acc_lambda_pos": round(best_pos, 4),
            "improvement": round(best_pos - accs[0.0], 4),
        })

    # beyond-paper: λ warm-up — ramp λ from 0 so the correction never
    # dominates the (initially weak) task gradient; targets the activation-
    # collapse failure of strong constant λ (EXPERIMENTS §Perf)
    import jax.numpy as jnp
    from repro.core.fedlite import make_train_step
    from repro.optim import sgd as _sgd
    q, L, lam = 288, 4, 1e-4
    pq = PQConfig(num_subvectors=q, num_clusters=L, kmeans_iters=5)
    model = FemnistCNN(pq=pq, lam=lam, client_batch=20)
    trainer = FederatedTrainer(model, _sgd(10 ** -1.5), data, cohort=10,
                               client_batch=20)
    sched = lambda step: lam * jnp.minimum(1.0, step / (rounds * 0.6))
    # swap the λ-schedule step into the stacked executor's sync slot (the
    # executor owns the jitted steps since the cohort-engine refactor)
    trainer.executor._step = make_train_step(model, _sgd(10 ** -1.5),
                                             lam_schedule=sched, donate=False)
    state, hist = trainer.run(rounds, jax.random.PRNGKey(1))
    eb = data.eval_batch(jax.random.PRNGKey(999), 512)
    rows.append({
        "name": f"q{q}_L{L}_lambda{lam:g}_WARMUP",
        "us_per_call": 0.0,
        "accuracy": round(float(model.accuracy(state.params, eb)), 4),
        "final_distortion": round(hist[-1].get("pq_distortion", 0), 3),
    })
    return rows


def main(fast: bool = True):
    emit(run(fast), "fig5_correction")


if __name__ == "__main__":
    main()
