"""Paper §5, SO Tag + SO NWP tasks (the paper's other two benchmarks).

  * SO Tag — one dense layer per side (cut d=2000), AdaGrad lr 10^-0.5,
    B=100 per client, cohort 10, multi-label Recall@5. Paper: up to 247×
    with minimal loss; Recall@5 can even IMPROVE under quantization
    (the dropout-like effect conjectured in §5).
  * SO NWP — Embedding+LSTM+Dense client (cut d=96), Dense server,
    Adam lr 0.01, cohort 50 (reduced here), next-word accuracy. Paper: up
    to 51× with minimal loss (d=96 is small, so ratios are modest).

Both use the synthetic federated stand-ins (see data/synthetic.py; real TFF
data is unavailable offline) with the paper's models and optimizers.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core.quantizer import PQConfig
from repro.data.synthetic import (make_federated_lm_data,
                                  make_federated_tag_data)
from repro.federated.runtime import FederatedTrainer
from repro.models.paper_models import SONwpLSTM, SOTagMLP
from repro.optim import adagrad, adam


def run(fast: bool = True):
    rows = []
    rounds = 100 if fast else 500

    # ---------------- SO Tag -------------------------------------------------
    data = make_federated_tag_data(num_clients=32, bow_dim=5000,
                                   num_tags=1000, seed=0)
    eb = data.eval_batch(jax.random.PRNGKey(99), 256)

    def tag_run(pq, lam):
        model = SOTagMLP(pq=pq, lam=lam, client_batch=100)
        tr = FederatedTrainer(model, adagrad(10 ** -0.5), data, cohort=10,
                              client_batch=100, quantize=pq is not None)
        state, hist = tr.run(rounds, jax.random.PRNGKey(0))
        return float(model.recall_at_5(state.params, eb)), hist[-1]

    r5_ref, _ = tag_run(None, 0.0)
    rows.append({"name": "so_tag_splitfed", "us_per_call": 0.0,
                 "recall_at_5": round(r5_ref, 4), "compression_ratio": 1.0})
    # paper grid: q in {1000, 250, 125}, L in {100, 20}; B=100, d=2000
    grid = [(250, 20)] if fast else \
        [(125, 100), (250, 20), (500, 20), (1000, 10)]
    for q, L in grid:
        pq = PQConfig(num_subvectors=q, num_clusters=L, kmeans_iters=5)
        r5, hist = tag_run(pq, 1e-3)   # paper's SO Tag λ range starts at 1e-3
        rows.append({
            "name": f"so_tag_fedlite_q{q}_L{L}", "us_per_call": 0.0,
            "recall_at_5": round(r5, 4),
            "compression_ratio": round(pq.compression_ratio(100, 2000), 1),
            "delta_vs_splitfed": round(r5 - r5_ref, 4),
        })

    # ---------------- SO NWP -------------------------------------------------
    jax.clear_caches()   # the tag phase leaves many compiled programs; CPU
    #                      XLA's JIT dylib pool can fail to materialize new
    #                      symbols otherwise (observed INTERNAL errors)
    vocab = 2000 if fast else 10_000
    data = make_federated_lm_data(num_clients=32, vocab=vocab, seed=0)
    eb = data.eval_batch(jax.random.PRNGKey(98), 128, seq=30)

    def nwp_run(pq, lam):
        model = SONwpLSTM(vocab=vocab, hidden=128 if fast else 670,
                          pq=pq, lam=lam, client_batch=16)
        tr = FederatedTrainer(model, adam(0.01), data, cohort=10,
                              client_batch=16, quantize=pq is not None,
                              batch_kwargs={"seq": 30})
        state, hist = tr.run(rounds, jax.random.PRNGKey(0))
        return float(model.accuracy(state.params, eb)), hist[-1]

    acc_ref, _ = nwp_run(None, 0.0)
    rows.append({"name": "so_nwp_splitfed", "us_per_call": 0.0,
                 "accuracy": round(acc_ref, 4), "compression_ratio": 1.0})
    # paper: q in {48, 12, 3}, L up to 960; d=96, 30 tokens x B samples
    for q, L in ([(12, 30)] if fast else [(48, 60), (12, 30), (3, 960)]):
        pq = PQConfig(num_subvectors=q, num_clusters=L, kmeans_iters=5)
        acc, hist = nwp_run(pq, 1e-3)
        n_vec = 16 * 30  # B tokens per client message
        rows.append({
            "name": f"so_nwp_fedlite_q{q}_L{L}", "us_per_call": 0.0,
            "accuracy": round(acc, 4),
            "compression_ratio": round(pq.compression_ratio(n_vec, 96), 1),
            "delta_vs_splitfed": round(acc - acc_ref, 4),
        })
    return rows


def main(fast: bool = True):
    emit(run(fast), "so_tasks")


if __name__ == "__main__":
    main()
