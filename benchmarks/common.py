"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax


def time_call(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Dict], prefix: str) -> None:
    """Print ``name,us_per_call,derived`` CSV rows."""
    for r in rows:
        name = f"{prefix}/{r.pop('name')}"
        us = r.pop("us_per_call", 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us:.1f},{derived}")
