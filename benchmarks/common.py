"""Shared benchmark utilities: timing, CSV emission, and the
``BENCH_<suite>.json`` snapshot format suites persist at the repo root so
perf/bytes trajectories are comparable across PRs.

Every ``write_bench_json`` additionally appends its rows to
``BENCH_history.jsonl`` (one line per row, keyed ``suite/name`` + git
sha) — the append-only record ``benchmarks/sentinel.py`` compares
against its committed baseline to catch silent regressions.

Scratch artifacts (event-log traces, Perfetto exports) go under
``benchmarks/out/`` (gitignored); only the JSON snapshots and the
history live at the repo root / in git.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import time
from typing import Callable, Dict, List

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
HISTORY_PATH = REPO_ROOT / "BENCH_history.jsonl"
# gitignored scratch dir for run artifacts (traces, perfetto exports)
OUT_DIR = REPO_ROOT / "benchmarks" / "out"


def out_path(name: str) -> pathlib.Path:
    """A path under the gitignored ``benchmarks/out/`` scratch dir."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    return OUT_DIR / name


def git_sha() -> str:
    """The current short commit sha, or ``"unknown"`` outside a checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def append_bench_history(suite: str, rows: List[Dict],
                         path: pathlib.Path = None) -> pathlib.Path:
    """Append one JSONL line per row: ``{suite, name, sha, t, metrics}``.

    ``metrics`` keeps only the numeric fields — the shape the sentinel's
    per-metric tolerance comparison consumes."""
    path = path or HISTORY_PATH
    sha = git_sha()
    now = time.time()
    with path.open("a", encoding="utf-8") as fh:
        for r in rows:
            metrics = {k: v for k, v in r.items()
                       if k != "name" and isinstance(v, (int, float))
                       and not isinstance(v, bool)}
            fh.write(json.dumps({
                "suite": suite, "name": str(r.get("name", "?")),
                "sha": sha, "t": now, "metrics": metrics,
            }, sort_keys=True) + "\n")
    return path


def write_bench_json(suite: str, rows: List[Dict], note: str = "") -> pathlib.Path:
    """Persist ``rows`` as ``BENCH_<suite>.json`` at the repo root.

    Call this BEFORE ``emit`` — emit pops ``name``/``us_per_call`` out of
    the very same row dicts while printing the CSV.

    Also appends every row to ``BENCH_history.jsonl`` for the
    bench-regression sentinel.
    """
    path = REPO_ROOT / f"BENCH_{suite}.json"
    # merge by row name into the existing snapshot: bench flags select
    # disjoint cell subsets (--chaos vs --fleet-scale vs the default
    # sweep), and the regression sentinel gates the committed snapshot —
    # one invocation must refresh its own rows without evicting the rest
    merged: Dict[str, Dict] = {}
    try:
        prior = json.loads(path.read_text())
        if isinstance(prior, dict) and prior.get("suite") == suite:
            merged = {r["name"]: r for r in prior.get("rows", [])
                      if isinstance(r, dict) and "name" in r}
    except (OSError, ValueError):
        pass
    merged.update((r["name"], r) for r in rows if "name" in r)
    payload = {
        "suite": suite,
        "jax_backend": jax.default_backend(),
        "note": note,
        "rows": list(merged.values()),
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=False) + "\n")
    append_bench_history(suite, rows)
    return path


def time_call(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Dict], prefix: str) -> None:
    """Print ``name,us_per_call,derived`` CSV rows."""
    for r in rows:
        name = f"{prefix}/{r.pop('name')}"
        us = r.pop("us_per_call", 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us:.1f},{derived}")
