"""Shared benchmark utilities: timing, CSV emission, and the
``BENCH_<suite>.json`` snapshot format suites persist at the repo root so
perf/bytes trajectories are comparable across PRs."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def write_bench_json(suite: str, rows: List[Dict], note: str = "") -> pathlib.Path:
    """Persist ``rows`` as ``BENCH_<suite>.json`` at the repo root.

    Call this BEFORE ``emit`` — emit pops ``name``/``us_per_call`` out of
    the very same row dicts while printing the CSV.
    """
    path = REPO_ROOT / f"BENCH_{suite}.json"
    payload = {
        "suite": suite,
        "jax_backend": jax.default_backend(),
        "note": note,
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=False) + "\n")
    return path


def time_call(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(rows: List[Dict], prefix: str) -> None:
    """Print ``name,us_per_call,derived`` CSV rows."""
    for r in rows:
        name = f"{prefix}/{r.pop('name')}"
        us = r.pop("us_per_call", 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us:.1f},{derived}")
