"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference, plus
the jnp assignment path used inside train steps. On CPU the interpret-mode
timing is NOT indicative of TPU performance — correctness + shape coverage
is the point; the jnp timings give the CPU substrate baseline."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import kmeans as km
from repro.kernels import ops, ref


def run(fast: bool = True):
    rows = []
    shapes = [(4096, 8, 16), (16384, 8, 16)] if fast else \
        [(4096, 8, 16), (16384, 8, 16), (65536, 8, 32), (16384, 64, 960)]
    for n, d, l in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        c = jax.random.normal(jax.random.PRNGKey(1), (l, d))
        lmask = jnp.ones(l, jnp.float32)

        us_ref = time_call(jax.jit(
            lambda a, b: ref.kmeans_assign_ref(a, b, lmask)[0]), x, c)
        rows.append({"name": f"assign_jnp_n{n}_d{d}_L{l}",
                     "us_per_call": us_ref})
        if n <= 16384:  # interpret mode is python-speed; keep it bounded
            us_k = time_call(
                lambda a, b: ops.kmeans_assign(a, b, interpret=True)[0],
                x, c, iters=1, warmup=1)
            rows.append({"name": f"assign_pallas_interpret_n{n}_d{d}_L{l}",
                         "us_per_call": us_k,
                         "note": "interpret-mode(correctness-only)"})

        us_f = time_call(jax.jit(
            lambda a, b: km.kmeans(a, 16, 4).distortion), x, jnp.zeros(()),
            iters=2)
        rows.append({"name": f"kmeans_full_n{n}_d{d}", "us_per_call": us_f})

    # flash-attention kernel parity check (interpret mode; TPU is the target)
    import math
    import numpy as np
    from repro.models.attention import row_block_attention
    B, S, H, Kv, hd = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kv, hd))
    v = jax.random.normal(ks[2], (B, S, Kv, hd))
    pos = jnp.arange(S)
    scale = 1.0 / math.sqrt(hd)
    ref_out = row_block_attention(q, k, v, pos, pos, window=None, q_chunk=S,
                                  scale=scale)
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        k.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd),
        v.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd),
        num_q_heads=H, num_kv_heads=Kv, scale=scale, block_q=64, block_k=64,
        interpret=True).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    err = float(np.abs(np.asarray(out - ref_out)).max())
    rows.append({"name": f"flash_attention_S{S}_H{H}kv{Kv}",
                 "us_per_call": 0.0, "max_err_vs_rowblock": round(err, 7),
                 "note": "interpret-mode parity; O(S*d) HBM traffic on TPU"})
    return rows


def main(fast: bool = True):
    emit(run(fast), "kernels")


if __name__ == "__main__":
    main()
