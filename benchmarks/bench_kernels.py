"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference, plus
the jnp assignment path used inside train steps. On CPU the interpret-mode
timing is NOT indicative of TPU performance — correctness + shape coverage
is the point; the jnp timings give the CPU substrate baseline."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import kmeans as km
from repro.core.quantizer import PQConfig, quantize
from repro.kernels import ops, ref


def bench_encode_backends(rows):
    """End-to-end grouped-PQ encode, jnp vs fused pallas, on the paper's
    FEMNIST cut shape: B=8 examples x d=9216, q=1152 -> one group of
    N=8*1152=9216 subvector rows of dim 8.

    Wall-clock rows time the two *current* backends — both are single-pass
    encodes (the jnp scan body does assign+gather+subtract per chunk, which
    XLA fuses). Off-TPU the pallas row is interpret mode (correctness
    substrate); the wall-clock comparison is only meaningful on TPU.

    The traffic-model row is the structural claim of the registry refactor:
    the seed did the encode as separate sweeps (assign pass inside kmeans,
    centroid-gather write, then the correction VJP re-read X and z̃ to form
    the residual — 3 reads + 2 writes per element) where the fused encode
    does 1 read + 2 writes. That is analytic, not measured here.
    """
    B, d, q, L = 8, 9216, 1152, 16
    z = jax.random.normal(jax.random.PRNGKey(3), (B, d))
    n_rows, dsub = B * q, d // q
    for backend in ("jnp", "pallas"):
        cfg = PQConfig(num_subvectors=q, num_clusters=L, kmeans_iters=4,
                       backend=backend)
        us = time_call(jax.jit(lambda zz, c=cfg: quantize(zz, c).dequantized),
                       z, iters=1 if backend == "pallas" else 2, warmup=1)
        rows.append({
            "name": f"pq_encode_femnist_cut_{backend}_N{n_rows}_D{dsub}_L{L}",
            "us_per_call": us,
            "note": ("single-pass fused kernel (interpret off-TPU)"
                     if backend == "pallas" else "single-pass XLA-fused scan"),
        })
    elem = n_rows * dsub * 4
    rows.append({
        "name": "pq_encode_femnist_cut_traffic_model",
        "us_per_call": 0.0,
        "fused_encode_bytes": 3 * elem,       # 1 read + 2 writes
        "seed_separate_sweeps_bytes": 5 * elem,  # 3 reads + 2 writes
        "note": "analytic: fused encode vs the seed's assign/gather/"
                "residual-recompute structure",
    })


def run(fast: bool = True):
    rows = []
    shapes = [(4096, 8, 16), (16384, 8, 16)] if fast else \
        [(4096, 8, 16), (16384, 8, 16), (65536, 8, 32), (16384, 64, 960)]
    for n, d, l in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        c = jax.random.normal(jax.random.PRNGKey(1), (l, d))
        lmask = jnp.ones(l, jnp.float32)

        us_ref = time_call(jax.jit(
            lambda a, b: ref.kmeans_assign_ref(a, b, lmask)[0]), x, c)
        rows.append({"name": f"assign_jnp_n{n}_d{d}_L{l}",
                     "us_per_call": us_ref})
        if n <= 16384:  # interpret mode is python-speed; keep it bounded
            us_k = time_call(
                lambda a, b: ops.kmeans_assign(a, b, interpret=True)[0],
                x, c, iters=1, warmup=1)
            rows.append({"name": f"assign_pallas_interpret_n{n}_d{d}_L{l}",
                         "us_per_call": us_k,
                         "note": "interpret-mode(correctness-only)"})

        us_f = time_call(jax.jit(
            lambda a, b: km.kmeans(a, 16, 4).distortion), x, jnp.zeros(()),
            iters=2)
        rows.append({"name": f"kmeans_full_n{n}_d{d}", "us_per_call": us_f})

    bench_encode_backends(rows)

    # flash-attention kernel parity check (interpret mode; TPU is the target)
    import math
    import numpy as np
    from repro.models.attention import row_block_attention
    B, S, H, Kv, hd = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kv, hd))
    v = jax.random.normal(ks[2], (B, S, Kv, hd))
    pos = jnp.arange(S)
    scale = 1.0 / math.sqrt(hd)
    ref_out = row_block_attention(q, k, v, pos, pos, window=None, q_chunk=S,
                                  scale=scale)
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        k.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd),
        v.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd),
        num_q_heads=H, num_kv_heads=Kv, scale=scale, block_q=64, block_k=64,
        interpret=True).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    err = float(np.abs(np.asarray(out - ref_out)).max())
    rows.append({"name": f"flash_attention_S{S}_H{H}kv{Kv}",
                 "us_per_call": 0.0, "max_err_vs_rowblock": round(err, 7),
                 "note": "interpret-mode parity; O(S*d) HBM traffic on TPU"})
    return rows


def main(fast: bool = True):
    emit(run(fast), "kernels")


if __name__ == "__main__":
    main()
