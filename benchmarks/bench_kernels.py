"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference, plus
the jnp assignment path used inside train steps. On CPU the interpret-mode
timing is NOT indicative of TPU performance — correctness + shape coverage
is the point; the jnp timings give the CPU substrate baseline.

Every run also writes ``BENCH_kernels.json`` at the repo root — one row per
kernel × backend (Lloyd update, scalarq quantize/pack, PQ encode, analytic
HBM-traffic models) — so the perf trajectory is tracked across PRs
(``benchmarks/run.py`` and the CI benchmark-smoke step both produce it)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call, write_bench_json
from repro.core import kmeans as km
from repro.core.quantizer import PQConfig, quantize
from repro.kernels import ops, ref


def bench_encode_backends(rows):
    """End-to-end grouped-PQ encode, jnp vs fused pallas, on the paper's
    FEMNIST cut shape: B=8 examples x d=9216, q=1152 -> one group of
    N=8*1152=9216 subvector rows of dim 8.

    Wall-clock rows time the two *current* backends — both are single-pass
    encodes (the jnp scan body does assign+gather+subtract per chunk, which
    XLA fuses). Off-TPU the pallas row is interpret mode (correctness
    substrate); the wall-clock comparison is only meaningful on TPU.

    The traffic-model row is the structural claim of the registry refactor:
    the seed did the encode as separate sweeps (assign pass inside kmeans,
    centroid-gather write, then the correction VJP re-read X and z̃ to form
    the residual — 3 reads + 2 writes per element) where the fused encode
    does 1 read + 2 writes. That is analytic, not measured here.
    """
    B, d, q, L = 8, 9216, 1152, 16
    z = jax.random.normal(jax.random.PRNGKey(3), (B, d))
    n_rows, dsub = B * q, d // q
    for backend in ("jnp", "pallas"):
        cfg = PQConfig(num_subvectors=q, num_clusters=L, kmeans_iters=4,
                       backend=backend)
        us = time_call(jax.jit(lambda zz, c=cfg: quantize(zz, c).dequantized),
                       z, iters=1 if backend == "pallas" else 2, warmup=1)
        rows.append({
            "name": f"pq_encode_femnist_cut_{backend}_N{n_rows}_D{dsub}_L{L}",
            "us_per_call": us,
            "note": ("single-pass fused kernel (interpret off-TPU)"
                     if backend == "pallas" else "single-pass XLA-fused scan"),
        })
    elem = n_rows * dsub * 4
    rows.append({
        "name": "pq_encode_femnist_cut_traffic_model",
        "us_per_call": 0.0,
        "fused_encode_bytes": 3 * elem,       # 1 read + 2 writes
        "seed_separate_sweeps_bytes": 5 * elem,  # 3 reads + 2 writes
        "note": "analytic: fused encode vs the seed's assign/gather/"
                "residual-recompute structure",
    })


def bench_lloyd_update(rows, fast: bool = True):
    """The Lloyd-update hot loop: jnp scan (one-hot matmul + centroid
    re-read per chunk) vs the fused Pallas kernel (one HBM sweep).

    Off-TPU the pallas rows run in interpret mode — parity is the claim,
    not wall-clock. The traffic-model row is the structural argument: per
    iteration the fused kernel reads X once and writes the O(L·D)
    accumulators, where the scan path additionally materializes a (N, L)
    one-hot and re-reads the centroids for the deviation gather."""
    import numpy as np
    shapes = [(4096, 8, 16)] if fast else [(4096, 8, 16), (65536, 8, 32)]
    for n, d, l in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        c = jax.random.normal(jax.random.PRNGKey(1), (l, d))
        w = jnp.ones((n,), jnp.float32)
        jnp_update = jax.jit(lambda a, cc: km._update_scan(
            km.get_backend("jnp").assign, a, w, cc, 4096))
        us_j = time_call(jnp_update, x, c)
        rows.append({"name": f"lloyd_update_jnp_n{n}_d{d}_L{l}",
                     "us_per_call": us_j,
                     "note": "scan: one-hot matmul + centroid re-read"})
        if n <= 16384:  # interpret mode is python-speed; keep it bounded
            us_p = time_call(lambda a, cc: ops.lloyd_update(
                a, cc, w), x, c, iters=1, warmup=1)
            ds_p, ct_p = ops.lloyd_update(x, c, w)
            ds_j, ct_j = jnp_update(x, c)
            err = float(np.abs(np.asarray(ds_p - ds_j)).max())
            rows.append({"name": f"lloyd_update_pallas_interpret_n{n}_d{d}_L{l}",
                         "us_per_call": us_p,
                         "max_err_vs_jnp": round(err, 7),
                         "note": "interpret-mode(correctness-only)"})
        f32 = 4
        rows.append({
            "name": f"lloyd_update_traffic_model_n{n}_d{d}_L{l}",
            "us_per_call": 0.0,
            "fused_bytes_per_iter": f32 * (n * d + n + l * d + l),
            "scan_bytes_per_iter": f32 * (2 * n * d + n + n * l + l * d + l),
            "note": "analytic: fused = 1 X read + O(L*D) accumulator writes;"
                    " scan adds a (N,L) one-hot + second centroid read",
        })


def bench_scalarq_kernels(rows):
    """The scalarq compressor's quantize + bit-pack hot loops, jnp vs the
    Pallas kernels (interpret off-TPU), next to the PQ encode rows."""
    import numpy as np
    n, d, bits = 2048, 64, 8
    x = jax.random.normal(jax.random.PRNGKey(5), (n, d))
    lo = jnp.min(x)
    scale = (jnp.max(x) - lo) / ((1 << bits) - 1)
    levels = (1 << bits) - 1

    def quant_jnp(a):
        codes = jnp.clip(jnp.round((a - lo) / scale), 0, levels) \
            .astype(jnp.int32)
        return codes, lo + codes.astype(jnp.float32) * scale

    us_j = time_call(jax.jit(quant_jnp), x)
    rows.append({"name": f"scalarq_quantize_jnp_n{n}_d{d}_b{bits}",
                 "us_per_call": us_j})
    us_p = time_call(lambda a: ops.scalar_quantize(a, lo, scale, bits),
                     x, iters=1, warmup=1)
    codes_j, _ = jax.jit(quant_jnp)(x)
    codes_p, _ = ops.scalar_quantize(x, lo, scale, bits)
    rows.append({"name": f"scalarq_quantize_pallas_interpret_n{n}_d{d}_b{bits}",
                 "us_per_call": us_p,
                 "codes_equal_jnp": bool((codes_j == codes_p).all()),
                 "note": "interpret-mode(correctness-only)"})

    flat = codes_j.reshape(-1)
    per_word = 32 // bits

    def pack_jnp(cc):
        mat = cc.reshape(-1, per_word).astype(jnp.uint32)
        weights = jnp.uint32(1) << (jnp.arange(per_word, dtype=jnp.uint32)
                                    * jnp.uint32(bits))
        return jnp.sum(mat * weights[None, :], axis=-1, dtype=jnp.uint32)

    us_pack_j = time_call(jax.jit(pack_jnp), flat)
    rows.append({"name": f"scalarq_pack_jnp_n{n * d}_b{bits}",
                 "us_per_call": us_pack_j})
    us_pack_p = time_call(lambda cc: ops.pack_codes(cc, bits),
                          flat, iters=1, warmup=1)
    words_j = jax.jit(pack_jnp)(flat)
    words_p = ops.pack_codes(flat, bits)
    rows.append({"name": f"scalarq_pack_pallas_interpret_n{n * d}_b{bits}",
                 "us_per_call": us_pack_p,
                 "words_equal_jnp": bool((words_j == words_p).all()),
                 "note": "interpret-mode(correctness-only)"})


def run(fast: bool = True):
    rows = []
    shapes = [(4096, 8, 16), (16384, 8, 16)] if fast else \
        [(4096, 8, 16), (16384, 8, 16), (65536, 8, 32), (16384, 64, 960)]
    for n, d, l in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        c = jax.random.normal(jax.random.PRNGKey(1), (l, d))
        lmask = jnp.ones(l, jnp.float32)

        us_ref = time_call(jax.jit(
            lambda a, b: ref.kmeans_assign_ref(a, b, lmask)[0]), x, c)
        rows.append({"name": f"assign_jnp_n{n}_d{d}_L{l}",
                     "us_per_call": us_ref})
        if n <= 16384:  # interpret mode is python-speed; keep it bounded
            us_k = time_call(
                lambda a, b: ops.kmeans_assign(a, b)[0],
                x, c, iters=1, warmup=1)
            rows.append({"name": f"assign_pallas_interpret_n{n}_d{d}_L{l}",
                         "us_per_call": us_k,
                         "note": "interpret-mode(correctness-only)"})

        us_f = time_call(jax.jit(
            lambda a, b: km.kmeans(a, 16, 4).distortion), x, jnp.zeros(()),
            iters=2)
        rows.append({"name": f"kmeans_full_n{n}_d{d}", "us_per_call": us_f})

    bench_lloyd_update(rows, fast)
    bench_encode_backends(rows)
    bench_scalarq_kernels(rows)

    # flash-attention kernel parity check (interpret mode; TPU is the target)
    import math
    import numpy as np
    from repro.models.attention import row_block_attention
    B, S, H, Kv, hd = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kv, hd))
    v = jax.random.normal(ks[2], (B, S, Kv, hd))
    pos = jnp.arange(S)
    scale = 1.0 / math.sqrt(hd)
    ref_out = row_block_attention(q, k, v, pos, pos, window=None, q_chunk=S,
                                  scale=scale)
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        k.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd),
        v.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd),
        num_q_heads=H, num_kv_heads=Kv, scale=scale, block_q=64,
        block_k=64).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    err = float(np.abs(np.asarray(out - ref_out)).max())
    rows.append({"name": f"flash_attention_S{S}_H{H}kv{Kv}",
                 "us_per_call": 0.0, "max_err_vs_rowblock": round(err, 7),
                 "note": "interpret-mode parity; O(S*d) HBM traffic on TPU"})
    # serialize before emit() strips the row keys
    write_bench_json(
        "kernels", rows,
        note="off-TPU pallas rows are interpret-mode (correctness, not "
             "speed); traffic_model rows are analytic bytes")
    return rows


def main(fast: bool = True):
    emit(run(fast), "kernels")


if __name__ == "__main__":
    main()
