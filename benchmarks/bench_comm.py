"""Paper Table 1 + §5 worked example: communication accounting.

Emits per-algorithm uplink bits for the paper's FEMNIST setting and for two
assigned big archs, and checks the §5 numbers: 490x activation compression;
~10x total-uplink reduction vs SplitFed; ~62x vs FedAvg with ~64x fewer
client-side trainable parameters.

Accounting width: the §5 worked example is checked at the paper's fixed
phi = 64 bits (PQConfig's default ``phi_bits``), passed explicitly below —
``tree_bits``/``comm_report`` now default to the *actual* dtype width, so
the big-arch rows report dtype-derived phi (32 for fp32 smoke configs).

The ``femnist_wire_measured`` row closes the loop analytically asserted
above: it pushes a real quantized batch through the bit-packed wire codec
(``federated/wire.py``) and reports measured payload bytes next to
``PQConfig.message_bits`` at the wire width — they must agree to within
the 24-byte header (+ <1 byte of code padding).

The ``femnist_downlink_measured`` row does the same for the OTHER
direction: the cut-layer gradient message through the acceptance downlink
codec (``chain:topk(k=0.1)+scalarq(bits=8)``) vs the dense fp32 baseline —
the measured reduction must be >= 8x and agree with the compressor's
``analytic_bits`` to within the per-stage headers.

The ``pq_delta`` rows measure the cross-round codebook-reuse win: round 1's
codebook (warm-started Lloyd) is shipped as the ``pq-delta`` wire kind —
8-bit quantized deltas against the acked round-0 reference — and the
measured codebook component must shrink >= 1.5x vs fresh fp16 codebooks
(asserted; acceptance criterion), with the closed-loop reconstruction
decoding bit-exactly.

The ``pq_delta_downlink`` row closes the same loop for the OTHER
direction: a pq downlink ships the cut-layer *gradient* as
codebooks+codes, and until the stateful hook
(``core/compressors.compress_downlink_stateful``) those codebooks were
fresh every round. A realistic gradient proxy (round-1 gradient = a small
drift of round-0's) is quantized warm-started from round 0's
`QuantizerState` and its codebook delta-encoded against the acked
reference — the measured downlink codebook component must shrink >= 1.5x
(asserted), decoding bit-exactly."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs.base import get_arch
from repro.core.compressors import make_compressor
from repro.core.fedlite import comm_report
from repro.core.quantizer import PQConfig, quantize
from repro.core.split import split_summary
from repro.federated import wire
from repro.launch.specs import default_pq, make_model
from repro.models.paper_models import FemnistCNN

PAPER_PHI = 64  # the paper's fixed accounting float width (bits)


def run(fast: bool = True):
    rows = []
    # ---- the paper's FEMNIST worked example (phi = 64, as in §5) ----------
    pq = PQConfig(num_subvectors=1152, num_clusters=2, kmeans_iters=2,
                  phi_bits=PAPER_PHI)
    model = FemnistCNN(pq=pq, lam=1e-4)
    params = model.init(jax.random.PRNGKey(0))
    s = split_summary(params, phi_bits=PAPER_PHI)
    B, d = 20, 9216
    act_bits = PAPER_PHI * d * B
    msg = pq.message_bits(B, d)
    client_bits = s["client_bits"]
    total_bits = client_bits + s["server_bits"]
    rows.append({
        "name": f"femnist_b20_q1152_L2_phi{PAPER_PHI}",
        "us_per_call": 0.0,
        "activation_compression": round(act_bits / msg, 1),        # paper: 490
        "uplink_vs_splitfed": round((client_bits + act_bits) /
                                    (client_bits + msg), 1),       # paper: ~10
        "uplink_vs_fedavg": round(total_bits / (client_bits + msg), 1),
        "client_param_fraction": round(s["client_fraction"], 4),   # ~1.6%
    })

    # ---- measured wire bytes vs the analytic bit count ---------------------
    # one real PQ encode through the bit-packed codec; fp16 codebooks on the
    # wire, so the analytic reference is message_bits at phi=16
    acts = jax.random.normal(jax.random.PRNGKey(1), (B, d))
    payload = wire.encode_bytes(quantize(acts, pq), "float16")
    analytic_bits = pq.message_bits(B, d, phi_bits=16)
    overhead_bits = len(payload) * 8 - analytic_bits
    assert len(payload) * 8 == wire.wire_bits(pq, B, d, "float16"), \
        "measured payload disagrees with wire_bits"
    assert 0 <= overhead_bits <= (wire.HEADER_BYTES + wire.CRC_BYTES) * 8 + 7, \
        f"wire overhead {overhead_bits} bits exceeds the documented frame"
    rows.append({
        "name": "femnist_wire_measured_b20_q1152_L2",
        "us_per_call": 0.0,
        "measured_bytes": len(payload),
        "analytic_phi16_bits": analytic_bits,
        "header_overhead_bits": overhead_bits,
        "measured_compression_vs_fp32": round(
            32 * d * B / (len(payload) * 8), 1),
    })

    # ---- measured DOWNLINK bytes: compressed gradient vs dense -------------
    # the cut-layer gradient message (shape-alike stand-in: the activations)
    # through the acceptance-criteria chain codec; dense fp32 is what the
    # pre-refactor downlink shipped every round
    dl = make_compressor("chain:topk(k=0.1)+scalarq(bits=8)")
    comp = dl.compress(acts)
    dl_payload = dl.wire_payload(comp)
    dense_bytes = acts.size * 4
    dl_analytic = dl.analytic_bits(B, d, phi_bits=32)
    reduction = dense_bytes / len(dl_payload)
    assert reduction >= 8.0, \
        f"downlink reduction {reduction:.2f}x below the 8x acceptance bar"
    # wire overhead: header + CRC trailer per chain stage + <1 B pad each
    dl_overhead = len(dl_payload) * 8 - dl_analytic
    assert 0 <= dl_overhead <= \
        2 * ((wire.HEADER_BYTES + wire.CRC_BYTES) * 8 + 7), \
        f"downlink wire overhead {dl_overhead} bits exceeds stage frames"
    rec = wire.reconstruct(wire.decode_payload(dl_payload))
    assert np.isfinite(rec).all()
    rows.append({
        "name": "femnist_downlink_measured_b20_topk0.1_sq8",
        "us_per_call": 0.0,
        "measured_bytes": len(dl_payload),
        "dense_bytes": dense_bytes,
        "analytic_bits": dl_analytic,
        "header_overhead_bits": dl_overhead,
        "measured_downlink_reduction": round(reduction, 1),
    })

    # ---- measured pq-delta codebook bytes vs fresh fp16 codebooks ----------
    # the LM-cut-shaped config (d/q = 8, L = 16 — launch/specs.default_pq):
    # this is where codebook bytes matter; FEMNIST's L=2 codebook is 32 B.
    # One recipe, both directions: round 0 ships full fp16 codebooks, the
    # receiver's decode is the acked reference, round 1 quantizes
    # warm-started and ships b-bit codebook deltas — bit-exact closed loop,
    # measured codebook component must shrink >= 1.5x (asserted).
    from repro.core.quantizer import quantize_stateful
    d_lm, q_lm = 512, 64
    pq_lm = PQConfig(num_subvectors=q_lm, num_clusters=16, kmeans_iters=4)

    def measure_pq_delta(t0, t1, row_name):
        qb0, qstate = quantize_stateful(t0, pq_lm)
        ref = wire.decode_bytes(
            wire.encode_bytes(qb0, "float16")).codebooks.astype(np.float32)
        qb1_, _ = quantize_stateful(t1, pq_lm, qstate)       # warm round
        full = wire.encode_bytes(qb1_, "float16")
        delta, recon = wire.encode_pq_delta(qb1_, ref, delta_bits=8)
        assert len(delta) * 8 == wire.pq_delta_wire_bits(
            pq_lm, t1.shape[0], d_lm, 8)
        wb = wire.decode_pq_delta(delta, ref)
        assert (wb.codes == np.asarray(qb1_.codes)).all()
        np.testing.assert_array_equal(wb.codebooks, recon)  # closed loop
        cb_full = int(np.prod(pq_lm.codebook_shape(d_lm))) * 2  # fp16 bytes
        # frame = header + body + CRC trailer in both directions; the
        # delta body's epoch word/scale live in its codebook component
        code_bytes = len(full) - wire.HEADER_BYTES - wire.CRC_BYTES - cb_full
        cb_delta = len(delta) - wire.HEADER_BYTES - wire.CRC_BYTES \
            - code_bytes
        reduction = cb_full / cb_delta
        assert reduction >= 1.5, \
            f"{row_name}: codebook reduction {reduction:.2f}x below 1.5x"
        return {
            "name": row_name,
            "us_per_call": 0.0,
            "codebook_bytes_full_fp16": cb_full,
            "codebook_bytes_delta": cb_delta,
            "codebook_reduction": round(reduction, 2),
            "payload_bytes_full": len(full),
            "payload_bytes_delta": len(delta),
            "delta_recon_max_err": round(
                float(np.abs(recon - np.asarray(qb1_.codebooks,
                                                np.float32)).max()), 6),
        }

    # uplink: round-1 activations drifted slightly from round 0's
    acts1 = jax.random.normal(jax.random.PRNGKey(2), (256, d_lm))
    acts2 = acts1 + 0.05 * jax.random.normal(jax.random.PRNGKey(3),
                                             (256, d_lm))
    rows.append(measure_pq_delta(acts1, acts2,
                                 "pq_delta_measured_lmcut_d512_L16_b8"))
    # downlink: the gradient message of a pq downlink, steady state — the
    # stateful-downlink (compress_downlink_stateful) analogue of the row
    # above, at gradient scale
    g1 = 0.01 * jax.random.normal(jax.random.PRNGKey(4), (256, d_lm))
    g2 = g1 + 0.05 * 0.01 * jax.random.normal(jax.random.PRNGKey(5),
                                              (256, d_lm))
    rows.append(measure_pq_delta(
        g1, g2, "pq_delta_downlink_measured_lmcut_d512_L16_b8"))

    # ---- big-arch accounting (smoke-size params, dtype-derived phi) --------
    for arch in ["llama3_8b", "mixtral_8x22b"]:
        cfg = get_arch(arch, smoke=True)
        m = make_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        rep = comm_report(m, p, tokens_per_client=4096)
        rows.append({
            "name": f"{arch}_smoke_tokens4096",
            "us_per_call": 0.0,
            "phi_bits": rep["phi_bits"],
            "activation_compression": round(
                rep["activation_compression_ratio"], 1),
            "uplink_vs_splitfed": round(
                rep["uplink_reduction_vs_splitfed"], 2),
            "uplink_vs_fedavg": round(rep["uplink_reduction_vs_fedavg"], 2),
        })

    # ---- full-size analytic accounting (no allocation; dtype-derived phi) --
    for arch in ["gemma_7b", "command_r_35b"]:
        cfg = get_arch(arch)
        pq_full = default_pq(cfg)
        tokens = 4096
        phi = jax.numpy.dtype(cfg.dtype).itemsize * 8
        act_bits = phi * cfg.d_model * tokens
        msg = pq_full.message_bits(tokens, cfg.d_model, phi_bits=phi)
        rows.append({
            "name": f"{arch}_full_analytic_phi{phi}",
            "us_per_call": 0.0,
            "activation_compression": round(act_bits / msg, 1),
            "head_params_fraction": round(
                cfg.padded_vocab * cfg.d_model / cfg.param_count(), 3),
        })
    # serialize before emit() strips the row keys
    write_bench_json(
        "comm", rows,
        note="Table 1 / §5 accounting: analytic bit counts plus measured "
             "wire payloads (pq, downlink chain, pq-delta codebooks)")
    return rows


def main(fast: bool = True):
    emit(run(fast), "table1_comm")


if __name__ == "__main__":
    main()
