"""Paper Table 1 + §5 worked example: communication accounting.

Emits per-algorithm uplink bits for the paper's FEMNIST setting and for two
assigned big archs, and checks the §5 numbers: 490x activation compression;
~10x total-uplink reduction vs SplitFed; ~62x vs FedAvg with ~64x fewer
client-side trainable parameters."""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs.base import get_arch
from repro.core.fedlite import comm_report
from repro.core.quantizer import PQConfig
from repro.core.split import split_summary, tree_bits
from repro.launch.specs import default_pq, make_model
from repro.models.paper_models import FemnistCNN


def run(fast: bool = True):
    rows = []
    # ---- the paper's FEMNIST worked example --------------------------------
    pq = PQConfig(num_subvectors=1152, num_clusters=2, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    params = model.init(jax.random.PRNGKey(0))
    s = split_summary(params)
    B, d = 20, 9216
    act_bits = 64 * d * B
    msg = pq.message_bits(B, d)
    client_bits = s["client_bits"]
    total_bits = client_bits + s["server_bits"]
    rows.append({
        "name": "femnist_b20_q1152_L2",
        "us_per_call": 0.0,
        "activation_compression": round(act_bits / msg, 1),        # paper: 490
        "uplink_vs_splitfed": round((client_bits + act_bits) /
                                    (client_bits + msg), 1),       # paper: ~10
        "uplink_vs_fedavg": round(total_bits / (client_bits + msg), 1),
        "client_param_fraction": round(s["client_fraction"], 4),   # ~1.6%
    })

    # ---- big-arch accounting (smoke-size params, full-size formulas) ------
    for arch in ["llama3_8b", "mixtral_8x22b"]:
        cfg = get_arch(arch, smoke=True)
        m = make_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        rep = comm_report(m, p, tokens_per_client=4096)
        rows.append({
            "name": f"{arch}_smoke_tokens4096",
            "us_per_call": 0.0,
            "activation_compression": round(
                rep["activation_compression_ratio"], 1),
            "uplink_vs_splitfed": round(
                rep["uplink_reduction_vs_splitfed"], 2),
            "uplink_vs_fedavg": round(rep["uplink_reduction_vs_fedavg"], 2),
        })

    # ---- full-size analytic accounting (no allocation) ---------------------
    for arch in ["gemma_7b", "command_r_35b"]:
        cfg = get_arch(arch)
        pq_full = default_pq(cfg)
        tokens = 4096
        act_bits = 64 * cfg.d_model * tokens
        msg = pq_full.message_bits(tokens, cfg.d_model)
        rows.append({
            "name": f"{arch}_full_analytic",
            "us_per_call": 0.0,
            "activation_compression": round(act_bits / msg, 1),
            "head_params_fraction": round(
                cfg.padded_vocab * cfg.d_model / cfg.param_count(), 3),
        })
    return rows


def main(fast: bool = True):
    emit(run(fast), "table1_comm")


if __name__ == "__main__":
    main()
