"""Bench-regression sentinel: compare fresh bench rows to the baseline.

Every ``write_bench_json`` call appends its rows to ``BENCH_history.jsonl``
(keyed ``suite/name`` + git sha); this module compares the *current*
``BENCH_<suite>.json`` snapshots against the committed
``benchmarks/BENCH_baseline.json`` and flags any metric whose delta
exceeds its per-metric tolerance.

Metrics fall into three classes:

  * **gated** (default) — deterministic outputs of the simulation:
    bytes/MB per round, simulated seconds, drop/quarantine counts,
    rates, compression ratios. These are bit-stable across runs on a
    fixed tree, so the tolerance is tight (1%) and a breach fails CI.
  * **loss-like** (name contains ``loss``) — deterministic too, but
    legitimately moved by any training-path PR; gated with a generous
    25% so only a blow-up trips the sentinel.
  * **noisy** (host wall-clock: ``us_per_call``, ``wall``, ``rss``,
    ``setup``, ``speedup``) — machine-dependent; tracked in the report,
    never gated. Wall-clock regressions are caught by the targeted
    bench assertions (e.g. the fleet-scale flights-overhead cell), not
    by cross-machine comparison.

CLI::

    python benchmarks/sentinel.py check               # red on regression
    python benchmarks/sentinel.py check --inject-regression  # self-test red
    python benchmarks/sentinel.py update              # rewrite baseline

``check`` only grades the intersection of baseline and current rows —
new rows are reported as untracked (add them with ``update``), vanished
rows as missing (a removed bench is a reviewable event, not a failure).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_PATH = REPO_ROOT / "benchmarks" / "BENCH_baseline.json"

_NOISY_RE = re.compile(
    r"(us_per_call|wall|rss|setup|speedup|s_per_round|overhead_x)")
_LOSS_RE = re.compile(r"loss")

#: (kind, relative tolerance or None=tracked-only)
GATED_REL_TOL = 0.01
LOSS_REL_TOL = 0.25


def metric_tolerance(metric: str) -> Optional[float]:
    """Per-metric relative tolerance; None = tracked, never gated."""
    if _NOISY_RE.search(metric):
        return None
    if _LOSS_RE.search(metric):
        return LOSS_REL_TOL
    return GATED_REL_TOL


def _numeric(row: Dict) -> Dict[str, float]:
    return {k: float(v) for k, v in row.items()
            if k != "name" and isinstance(v, (int, float))
            and not isinstance(v, bool)}


def load_current(root: pathlib.Path = REPO_ROOT) -> Dict[str, Dict[str, float]]:
    """``{"suite/name": {metric: value}}`` from every BENCH_<suite>.json."""
    out: Dict[str, Dict[str, float]] = {}
    for path in sorted(root.glob("BENCH_*.json")):
        try:
            doc = json.loads(path.read_text())
        except ValueError:
            continue
        if not isinstance(doc, dict) or "rows" not in doc:
            continue  # e.g. a stray perfetto export
        suite = doc.get("suite") or path.stem.replace("BENCH_", "")
        for row in doc["rows"]:
            if isinstance(row, dict) and "name" in row:
                out[f"{suite}/{row['name']}"] = _numeric(row)
    return out


def load_baseline(path: pathlib.Path = BASELINE_PATH) -> Dict[str, Dict[str, float]]:
    doc = json.loads(path.read_text())
    return {k: {m: float(v) for m, v in row.items()}
            for k, row in doc.get("rows", {}).items()}


def compare(baseline: Dict[str, Dict[str, float]],
            current: Dict[str, Dict[str, float]],
            ) -> Tuple[List[Dict], List[str], List[str]]:
    """Grade current vs baseline on their intersection.

    Returns ``(deltas, untracked, missing)``; each delta dict carries
    ``key, metric, base, cur, rel, tol, gated, flagged``."""
    deltas: List[Dict] = []
    untracked = sorted(set(current) - set(baseline))
    missing = sorted(set(baseline) - set(current))
    for key in sorted(set(baseline) & set(current)):
        base_row, cur_row = baseline[key], current[key]
        for metric in sorted(set(base_row) & set(cur_row)):
            base, cur = base_row[metric], cur_row[metric]
            denom = max(abs(base), 1e-12)
            rel = abs(cur - base) / denom
            tol = metric_tolerance(metric)
            gated = tol is not None
            deltas.append({
                "key": key, "metric": metric, "base": base, "cur": cur,
                "rel": rel, "tol": tol, "gated": gated,
                "flagged": bool(gated and rel > tol),
            })
    return deltas, untracked, missing


def inject_regression(current: Dict[str, Dict[str, float]]) -> str:
    """Perturb the first gated metric by 10x its tolerance (self-test)."""
    for key in sorted(current):
        for metric in sorted(current[key]):
            tol = metric_tolerance(metric)
            if tol is None:
                continue
            base = current[key][metric]
            bump = (abs(base) or 1.0) * tol * 10.0
            current[key][metric] = base + bump
            return f"{key}:{metric}"
    raise SystemExit("no gated metric found to perturb")


def cmd_check(args: argparse.Namespace) -> int:
    if not args.baseline.exists():
        print(f"sentinel: no baseline at {args.baseline}; run "
              f"'python benchmarks/sentinel.py update' first",
              file=sys.stderr)
        return 2
    baseline = load_baseline(args.baseline)
    current = load_current(args.root)
    if args.inject_regression:
        where = inject_regression(current)
        print(f"sentinel: injected synthetic regression at {where}")
    deltas, untracked, missing = compare(baseline, current)
    flagged = [d for d in deltas if d["flagged"]]
    for d in flagged:
        print(f"REGRESSION  {d['key']} {d['metric']}: "
              f"{d['base']:g} -> {d['cur']:g} "
              f"(rel {d['rel']:.3%} > tol {d['tol']:.0%})")
    if args.verbose:
        for d in deltas:
            if d["flagged"]:
                continue
            kind = "gated" if d["gated"] else "tracked"
            print(f"ok ({kind})  {d['key']} {d['metric']}: "
                  f"{d['base']:g} -> {d['cur']:g} (rel {d['rel']:.3%})")
    for key in untracked:
        print(f"untracked   {key} (not in baseline; 'update' to adopt)")
    for key in missing:
        print(f"missing     {key} (in baseline, no current row)")
    n_gated = sum(d["gated"] for d in deltas)
    print(f"sentinel: {len(flagged)} regression(s) across "
          f"{n_gated} gated metric(s) "
          f"({len(deltas) - n_gated} tracked-only)")
    return 1 if flagged else 0


def cmd_update(args: argparse.Namespace) -> int:
    current = load_current(args.root)
    payload = {"note": "bench-regression sentinel baseline; refresh with "
                       "'python benchmarks/sentinel.py update'",
               "rows": current}
    args.baseline.write_text(
        json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"sentinel: baseline updated with {len(current)} row(s) "
          f"-> {args.baseline}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("command", choices=["check", "update"])
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE_PATH)
    ap.add_argument("--root", type=pathlib.Path, default=REPO_ROOT,
                    help="directory holding the BENCH_<suite>.json files")
    ap.add_argument("--inject-regression", action="store_true",
                    help="perturb one gated metric 10x past tolerance "
                         "(CI self-test: check must go red)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    return cmd_check(args) if args.command == "check" else cmd_update(args)


if __name__ == "__main__":
    raise SystemExit(main())
